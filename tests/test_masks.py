"""Taxonomy invariants for the paper's Fig-1 dropout cases (core/masks.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import masks
from repro.core.masks import BatchPattern, TimePattern


KEY = jax.random.PRNGKey(42)


class TestExactK:
    @pytest.mark.parametrize("hidden,rate,bs", [
        (64, 0.5, 1), (64, 0.5, 8), (128, 0.65, 1), (1024, 0.3, 128),
        (650, 0.5, 1), (1500, 0.65, 1), (2048, 0.25, 128),
    ])
    def test_counts(self, hidden, rate, bs):
        nb = masks.num_blocks(hidden, bs)
        nd = masks.num_dropped_blocks(hidden, rate, bs)
        nk = masks.num_kept_blocks(hidden, rate, bs)
        assert nd + nk == nb
        assert nd >= 1  # rate > 0 drops something
        assert nk >= 1  # never drops everything
        # ceil: realized rate >= requested rate (within one block)
        assert nd / nb >= rate - 1e-9 or nd == nb - 1

    def test_indivisible_raises(self):
        with pytest.raises(ValueError):
            masks.num_blocks(100, 8)

    def test_zero_rate(self):
        assert masks.num_dropped_blocks(64, 0.0, 1) == 0
        assert masks.kept_units(64, 0.0, 8) == 64


class TestSampling:
    def test_sorted_unique_in_range(self):
        kb = masks.sample_keep_blocks(KEY, 128, 0.5, 8)
        kb = np.asarray(kb)
        assert kb.dtype == np.int32
        assert (np.diff(kb) > 0).all()           # strictly sorted => unique
        assert kb.min() >= 0 and kb.max() < 16
        assert len(kb) == masks.num_kept_blocks(128, 0.5, 8)

    def test_different_keys_different_masks(self):
        a = masks.sample_keep_blocks(KEY, 1024, 0.5, 1)
        b = masks.sample_keep_blocks(jax.random.fold_in(KEY, 1), 1024, 0.5, 1)
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    def test_same_key_same_mask(self):
        a = masks.sample_keep_blocks(KEY, 1024, 0.5, 1)
        b = masks.sample_keep_blocks(KEY, 1024, 0.5, 1)
        assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_mask_expansion(self):
        kb = masks.sample_keep_blocks(KEY, 64, 0.5, 8)
        m = masks.keep_blocks_to_mask(kb, 64, 8)
        assert m.shape == (64,)
        assert float(m.sum()) == masks.kept_units(64, 0.5, 8)
        ids = masks.keep_blocks_to_unit_ids(kb, 8)
        assert np.array_equal(np.sort(np.asarray(ids)), np.where(np.asarray(m) > 0)[0])


class TestCaseTaxonomy:
    """Fig. 1: the four cases differ exactly in batch-uniformity x time-variation."""

    def test_structured_mask_uniform_within_batch(self):
        m = masks.structured_mask(KEY, batch=16, hidden=64, rate=0.5)
        m = np.asarray(m)
        assert (m == m[0]).all()                 # every row identical (Case-III/IV)

    def test_random_mask_varies_within_batch(self):
        m = np.asarray(masks.random_mask(KEY, 64, 256, 0.5))
        assert not (m == m[0]).all()             # Case-I/II: per-sample masks

    def test_per_step_keys_vary_fixed_keys_do_not(self):
        ks = masks.time_keys(KEY, 5, TimePattern.PER_STEP)
        assert not np.array_equal(np.asarray(ks[0]), np.asarray(ks[1]))
        kf = masks.time_keys(KEY, 5, TimePattern.FIXED)
        assert np.array_equal(np.asarray(kf[0]), np.asarray(kf[4]))

    def test_case_registry(self):
        assert masks.CASES["case1"] == (BatchPattern.RANDOM, TimePattern.PER_STEP)
        assert masks.CASES["case2"] == (BatchPattern.RANDOM, TimePattern.FIXED)
        assert masks.CASES["case3"] == (BatchPattern.STRUCTURED, TimePattern.PER_STEP)
        assert masks.CASES["case4"] == (BatchPattern.STRUCTURED, TimePattern.FIXED)


class TestInvertedScale:
    def test_expectation_preserved(self):
        """E[scaled masked x] == x over mask draws (exact for exact-k)."""
        hidden, rate, bs = 64, 0.5, 8
        scale = masks.inverted_scale(rate, hidden, bs)
        x = jnp.ones((hidden,))
        acc = np.zeros((hidden,))
        n = 400
        for i in range(n):
            kb = masks.sample_keep_blocks(jax.random.fold_in(KEY, i), hidden, rate, bs)
            m = masks.keep_blocks_to_mask(kb, hidden, bs)
            acc += np.asarray(x * m * scale)
        np.testing.assert_allclose(acc / n, np.ones(hidden), atol=0.15)

    def test_scale_value(self):
        # 64 units, rate .5, bs 8 -> 8 blocks, drop 4, keep 32 units -> scale 2.0
        assert masks.inverted_scale(0.5, 64, 8) == pytest.approx(2.0)
        assert masks.inverted_scale(0.0, 64, 8) == 1.0


@settings(max_examples=30, deadline=None)
@given(
    nb=st.integers(2, 32),
    bs=st.sampled_from([1, 4, 8, 128]),
    rate=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_exact_k(nb, bs, rate, seed):
    """Property: sampled keep set always has the static exact-k size, is sorted,
    unique, in range; kept+dropped == total; scale * kept == hidden."""
    hidden = nb * bs
    kb = np.asarray(masks.sample_keep_blocks(
        jax.random.PRNGKey(seed), hidden, rate, bs))
    nk = masks.num_kept_blocks(hidden, rate, bs)
    assert kb.shape == (nk,)
    assert (np.diff(kb) > 0).all() if len(kb) > 1 else True
    assert kb.min() >= 0 and kb.max() < nb
    scale = masks.inverted_scale(rate, hidden, bs)
    assert scale * masks.kept_units(hidden, rate, bs) == pytest.approx(hidden)
