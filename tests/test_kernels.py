"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU).

Every kernel variant is swept over shapes x dtypes x rates and asserted
allclose against ref.py. interpret=True executes the kernel body in Python,
so these tests validate index_map/BlockSpec logic exactly as the TPU would
see it (modulo compilation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masks
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def mk(shape, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestGatherMatmulBRows:
    """FP variant: y = a[:, kept] @ b[kept, :]."""

    @pytest.mark.parametrize("M,H,N,bs,rate", [
        (8, 64, 32, 8, 0.5),
        (16, 128, 128, 8, 0.25),
        (128, 256, 512, 128, 0.5),     # production tile sizes
        (5, 48, 17, 8, 0.5),           # unaligned M and N (padding path)
        (1, 64, 256, 8, 0.65),         # decode-like M=1
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, M, H, N, bs, rate, dtype):
        a, b = mk((M, H), dtype, 1), mk((H, N), dtype, 2)
        kb = masks.sample_keep_blocks(KEY, H, rate, bs)
        y = ops.gather_matmul(a, b, kb, block_size=bs, gather="b_rows")
        y_ref = ref.gather_matmul_ref(a, b, kb, block_size=bs, gather="b_rows")
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32), **TOL[dtype])

    def test_a_compact(self):
        M, H, N, bs, rate = 8, 64, 32, 8, 0.5
        a, b = mk((M, H), jnp.float32, 1), mk((H, N), jnp.float32, 2)
        kb = masks.sample_keep_blocks(KEY, H, rate, bs)
        ids = masks.keep_blocks_to_unit_ids(kb, bs)
        a_c = jnp.take(a, ids, axis=1)
        y = ops.gather_matmul(a_c, b, kb, block_size=bs, gather="b_rows",
                              a_is_compact=True)
        y_ref = ref.gather_matmul_ref(a, b, kb, block_size=bs, gather="b_rows")
        np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


class TestGatherMatmulBRowsT:
    """BP variant: dx_c = dy @ b[kept, :].T (compact output)."""

    @pytest.mark.parametrize("M,H,N,bs,rate", [
        (8, 64, 32, 8, 0.5),
        (16, 256, 96, 8, 0.25),
        (128, 512, 256, 128, 0.5),
        (7, 64, 33, 8, 0.5),
    ])
    def test_sweep(self, M, H, N, bs, rate):
        dy, b = mk((M, N), jnp.float32, 3), mk((H, N), jnp.float32, 4)
        kb = masks.sample_keep_blocks(KEY, H, rate, bs)
        y = ops.gather_matmul(dy, b, kb, block_size=bs, gather="b_rows",
                              transpose_b=True)
        y_ref = ref.gather_matmul_ref(dy, b, kb, block_size=bs, gather="b_rows",
                                      transpose_b=True)
        # rtol scaled for fp32 accumulation-order differences at larger K
        np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


class TestGatherMatmulBCols:
    """FFN-up variant: y_c = a @ b[:, kept] (compact output)."""

    @pytest.mark.parametrize("M,K,F,bs,rate", [
        (8, 32, 64, 8, 0.5),
        (16, 96, 256, 8, 0.25),
        (128, 256, 1024, 128, 0.5),
        (6, 40, 48, 8, 0.5),
    ])
    def test_sweep(self, M, K, F, bs, rate):
        a, b = mk((M, K), jnp.float32, 5), mk((K, F), jnp.float32, 6)
        kb = masks.sample_keep_blocks(KEY, F, rate, bs)
        y = ops.gather_matmul(a, b, kb, block_size=bs, gather="b_cols")
        y_ref = ref.gather_matmul_ref(a, b, kb, block_size=bs, gather="b_cols")
        # rtol scaled for fp32 accumulation-order differences at larger K
        np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


class TestGatherMatmulStepped:
    """Scheduled variant: (T, nk) ids table as extra leading grid axis."""

    @pytest.mark.parametrize("T,M,H,N,bs,rate", [
        (4, 8, 64, 32, 8, 0.5),
        (6, 16, 128, 96, 8, 0.25),
        (3, 128, 256, 256, 128, 0.5),   # production tile sizes
        (5, 7, 64, 33, 8, 0.5),         # unaligned M and N (padding path)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_fp_sweep(self, T, M, H, N, bs, rate, dtype):
        a, b = mk((T, M, H), dtype, 11), mk((H, N), dtype, 12)
        kb = jnp.stack([masks.sample_keep_blocks(
            jax.random.fold_in(KEY, t), H, rate, bs) for t in range(T)])
        ids = jnp.stack([masks.keep_blocks_to_unit_ids(kb[t], bs)
                         for t in range(T)])
        a_c = jnp.take_along_axis(a, ids[:, None, :], axis=2)
        y = ops.gather_matmul_stepped(a_c, b, kb, block_size=bs,
                                      a_is_compact=True)
        y_ref = ref.gather_matmul_stepped_ref(a_c, b, kb, block_size=bs,
                                              a_is_compact=True)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32), **TOL[dtype])
        # gathering a's columns inside the kernel must agree too
        y2 = ops.gather_matmul_stepped(a, b, kb, block_size=bs)
        np.testing.assert_allclose(np.asarray(y2, np.float32),
                                   np.asarray(y_ref, np.float32), **TOL[dtype])

    @pytest.mark.parametrize("T,M,H,N,bs,rate", [
        (4, 8, 64, 32, 8, 0.5),
        (3, 16, 256, 96, 8, 0.25),
        (5, 7, 64, 33, 8, 0.5),
    ])
    def test_bp_sweep(self, T, M, H, N, bs, rate):
        dy, b = mk((T, M, N), jnp.float32, 13), mk((H, N), jnp.float32, 14)
        kb = jnp.stack([masks.sample_keep_blocks(
            jax.random.fold_in(KEY, t), H, rate, bs) for t in range(T)])
        y = ops.gather_matmul_stepped(dy, b, kb, block_size=bs,
                                      transpose_b=True)
        y_ref = ref.gather_matmul_stepped_ref(dy, b, kb, block_size=bs,
                                              transpose_b=True)
        np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)

    def test_per_step_masks_differ(self):
        """Each step really contracts its own kept blocks (not step 0's)."""
        T, M, H, N, bs = 3, 4, 32, 16, 8
        a, b = mk((T, M, H), jnp.float32, 15), mk((H, N), jnp.float32, 16)
        kb = jnp.stack([masks.sample_keep_blocks(
            jax.random.fold_in(KEY, 100 + t), H, 0.5, bs) for t in range(T)])
        y = ops.gather_matmul_stepped(a, b, kb, block_size=bs)
        y0 = ops.gather_matmul_stepped(
            a, b, jnp.broadcast_to(kb[:1], kb.shape), block_size=bs)
        assert not np.allclose(np.asarray(y), np.asarray(y0))


class TestLSTMScan:
    """Fused persistent-scan recurrence vs the per-step jnp oracle.

    Sweeps RH mode (structured / random-dense / off) x time pattern
    (per-step / FIXED one-row) x impl (pallas interpret / xla), forward and
    gradients through the custom_vjp (d gx/U/h0/c0 vs autodiff-of-oracle).
    """

    def _setup(self, T, B, H, dtype=jnp.float32):
        gx = mk((T, B, 4 * H), dtype, 21) * 0.3
        u = mk((H, 4 * H), dtype, 22) * 0.1
        h0 = mk((B, H), dtype, 23) * 0.5
        c0 = mk((B, H), dtype, 24) * 0.5
        return gx, u, h0, c0

    def _kb(self, T, H, bs, rate, seed=0):
        return jnp.stack([masks.sample_keep_blocks(
            jax.random.fold_in(KEY, seed + t), H, rate, bs)
            for t in range(T)])

    def _check(self, kw, T=5, B=3, H=16, fb=0.0, dtype=jnp.float32,
               grads=True):
        gx, u, h0, c0 = self._setup(T, B, H, dtype)
        ys_ref, (hf_ref, cf_ref) = ref.lstm_scan_ref(
            gx, u, h0, c0, forget_bias=fb, **kw)
        for impl in ("xla", "pallas"):
            ys, (hf, cf) = ops.lstm_scan(gx, u, h0, c0, forget_bias=fb,
                                         impl=impl, **kw)
            np.testing.assert_allclose(
                np.asarray(ys, np.float32), np.asarray(ys_ref, np.float32),
                err_msg=f"{impl} ys", **TOL[dtype])
            np.testing.assert_allclose(
                np.asarray(cf, np.float32), np.asarray(cf_ref, np.float32),
                err_msg=f"{impl} c_fin", **TOL[dtype])
            if not grads:
                continue

            def loss(gx, u, h0, c0, impl=impl):
                ys, (hf, cf) = ops.lstm_scan(gx, u, h0, c0, forget_bias=fb,
                                             impl=impl, **kw)
                return (ys ** 2).sum() + (hf * cf).sum()

            def loss_ref(gx, u, h0, c0):
                ys, (hf, cf) = ref.lstm_scan_ref(gx, u, h0, c0,
                                                 forget_bias=fb, **kw)
                return (ys ** 2).sum() + (hf * cf).sum()

            g = jax.grad(loss, argnums=(0, 1, 2, 3))(gx, u, h0, c0)
            gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(gx, u, h0, c0)
            for a, b, nm in zip(g, gr, ("gx", "u", "h0", "c0")):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    rtol=2e-4, atol=2e-4, err_msg=f"{impl} d{nm}")

    @pytest.mark.parametrize("T,B,H,bs,rate", [
        (5, 3, 16, 4, 0.5),
        (7, 2, 32, 8, 0.25),
        (3, 4, 24, 1, 0.5),            # paper-faithful unit columns
        (4, 1, 16, 4, 0.65),           # B=1 decode-like
    ])
    def test_structured(self, T, B, H, bs, rate):
        kb = self._kb(T, H, bs, rate)
        self._check(dict(keep_blocks=kb, block_size=bs,
                         scale=masks.inverted_scale(rate, H, bs)),
                    T=T, B=B, H=H)

    def test_structured_fixed_one_row(self):
        """A (1, nk) FIXED table == the same row broadcast to all T steps."""
        T, B, H, bs = 6, 3, 16, 4
        kb = self._kb(T, H, bs, 0.5)
        kw = dict(block_size=bs, scale=2.0)
        for impl in ("xla", "pallas"):
            y1, _ = ops.lstm_scan(*self._setup(T, B, H), impl=impl,
                                  keep_blocks=kb[:1], **kw)
            y2, _ = ops.lstm_scan(*self._setup(T, B, H), impl=impl,
                                  keep_blocks=jnp.broadcast_to(
                                      kb[:1], (T, kb.shape[1])), **kw)
            np.testing.assert_allclose(y1, y2, rtol=1e-6, atol=1e-6,
                                       err_msg=impl)
        self._check(dict(keep_blocks=kb[:1], block_size=bs, scale=2.0),
                    T=T, B=B, H=H)

    @pytest.mark.parametrize("fixed", [False, True])
    def test_dense_mask(self, fixed):
        T, B, H = 5, 3, 16
        dm = (jax.random.uniform(jax.random.fold_in(KEY, 30),
                                 (1 if fixed else T, B, H)) > 0.5
              ).astype(jnp.float32)
        self._check(dict(dense_mask=dm, scale=2.0), T=T, B=B, H=H)

    @pytest.mark.parametrize("fb", [0.0, 1.0])
    def test_no_dropout(self, fb):
        self._check({}, fb=fb)

    def test_bf16(self):
        kb = self._kb(4, 16, 4, 0.5)
        self._check(dict(keep_blocks=kb, block_size=4, scale=2.0),
                    T=4, B=2, H=16, dtype=jnp.bfloat16, grads=False)

    def test_per_step_masks_differ(self):
        """Each step really gathers its own kept blocks (not step 0's)."""
        T, B, H, bs = 4, 3, 32, 8
        gx, u, h0, c0 = self._setup(T, B, H)
        kb = self._kb(T, H, bs, 0.5, seed=100)
        kw = dict(block_size=bs, scale=2.0)
        for impl in ("xla", "pallas"):
            y, _ = ops.lstm_scan(gx, u, h0, c0, impl=impl,
                                 keep_blocks=kb, **kw)
            y0, _ = ops.lstm_scan(gx, u, h0, c0, impl=impl,
                                  keep_blocks=jnp.broadcast_to(
                                      kb[:1], kb.shape), **kw)
            assert not np.allclose(np.asarray(y), np.asarray(y0)), impl

    def test_both_masks_raises(self):
        gx, u, h0, c0 = self._setup(3, 2, 16)
        kb = self._kb(3, 16, 4, 0.5)
        dm = jnp.ones((3, 2, 16))
        with pytest.raises(ValueError):
            ops.lstm_scan(gx, u, h0, c0, keep_blocks=kb, dense_mask=dm,
                          block_size=4)


class TestSLSTMScan:
    """Fused persistent-scan sLSTM vs the per-step jnp oracle.

    Mirrors TestLSTMScan over the xLSTM cell (exponential gating, (c, n, m)
    normalizer/stabilizer carries, per-head block-diagonal R): RH mode
    (structured / random-dense / off) x time pattern (per-step / FIXED
    one-row) x impl (pallas interpret / xla) x dtype, forward and gradients
    through the custom_vjp (d xg/R/h0/c0/n0/m0 vs autodiff-of-oracle).
    """

    def _setup(self, T, B, H, dh, dtype=jnp.float32, fresh=False):
        xg = mk((T, B, H, 4 * dh), dtype, 41) * 0.3
        r = mk((H, dh, 4 * dh), dtype, 42) * 0.2
        if fresh:          # canonical start: zeros + -1e30 stabilizer
            z = jnp.zeros((B, H, dh), dtype)
            return xg, r, z, z, z, jnp.full((B, H, dh), -1e30, dtype)
        h0 = mk((B, H, dh), dtype, 43) * 0.5
        c0 = mk((B, H, dh), dtype, 44) * 0.5
        n0 = jnp.abs(mk((B, H, dh), dtype, 45)) + 0.5   # mid-stream handoff
        m0 = mk((B, H, dh), dtype, 46) * 0.3
        return xg, r, h0, c0, n0, m0

    def _kb(self, T, dh, bs, rate, seed=0):
        return jnp.stack([masks.sample_keep_blocks(
            jax.random.fold_in(KEY, seed + t), dh, rate, bs)
            for t in range(T)])

    def _check(self, kw, T=5, B=2, H=3, dh=16, dtype=jnp.float32,
               grads=True, fresh=False):
        args = self._setup(T, B, H, dh, dtype, fresh=fresh)
        ys_ref, (hf_ref, (cf_ref, nf_ref, mf_ref)) = ref.slstm_scan_ref(
            *args, **kw)
        for impl in ("xla", "pallas"):
            ys, (hf, (cf, nf, mf)) = ops.slstm_scan(*args, impl=impl, **kw)
            np.testing.assert_allclose(
                np.asarray(ys, np.float32), np.asarray(ys_ref, np.float32),
                err_msg=f"{impl} ys", **TOL[dtype])
            for a, b, nm in ((cf, cf_ref, "c"), (nf, nf_ref, "n"),
                             (mf, mf_ref, "m")):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    err_msg=f"{impl} {nm}_fin", **TOL[dtype])
            if not grads:
                continue

            def loss(xg, r, h0, c0, n0, m0, impl=impl):
                ys, (hf, (cf, nf, mf)) = ops.slstm_scan(
                    xg, r, h0, c0, n0, m0, impl=impl, **kw)
                return ((ys ** 2).sum() + (hf * cf).sum()
                        + 0.1 * nf.sum() + 0.01 * mf.sum())

            def loss_ref(xg, r, h0, c0, n0, m0):
                ys, (hf, (cf, nf, mf)) = ref.slstm_scan_ref(
                    xg, r, h0, c0, n0, m0, **kw)
                return ((ys ** 2).sum() + (hf * cf).sum()
                        + 0.1 * nf.sum() + 0.01 * mf.sum())

            g = jax.grad(loss, argnums=tuple(range(6)))(*args)
            gr = jax.grad(loss_ref, argnums=tuple(range(6)))(*args)
            for a, b, nm in zip(g, gr, ("xg", "r", "h0", "c0", "n0", "m0")):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    rtol=2e-4, atol=2e-4, err_msg=f"{impl} d{nm}")

    @pytest.mark.parametrize("T,B,H,dh,bs,rate", [
        (5, 2, 3, 16, 4, 0.5),
        (7, 2, 2, 32, 8, 0.25),
        (3, 3, 4, 24, 1, 0.5),         # paper-faithful unit columns
        (4, 1, 2, 16, 4, 0.65),        # B=1 decode-like
    ])
    def test_structured(self, T, B, H, dh, bs, rate):
        kb = self._kb(T, dh, bs, rate)
        self._check(dict(keep_blocks=kb, block_size=bs,
                         scale=masks.inverted_scale(rate, dh, bs)),
                    T=T, B=B, H=H, dh=dh)

    def test_structured_fixed_one_row(self):
        """A (1, nk) FIXED table == the same row broadcast to all T steps."""
        T, B, H, dh, bs = 6, 2, 3, 16, 4
        kb = self._kb(T, dh, bs, 0.5)
        kw = dict(block_size=bs, scale=2.0)
        for impl in ("xla", "pallas"):
            y1, _ = ops.slstm_scan(*self._setup(T, B, H, dh), impl=impl,
                                   keep_blocks=kb[:1], **kw)
            y2, _ = ops.slstm_scan(*self._setup(T, B, H, dh), impl=impl,
                                   keep_blocks=jnp.broadcast_to(
                                       kb[:1], (T, kb.shape[1])), **kw)
            np.testing.assert_allclose(y1, y2, rtol=1e-6, atol=1e-6,
                                       err_msg=impl)
        self._check(dict(keep_blocks=kb[:1], block_size=bs, scale=2.0),
                    T=T, B=B, H=H, dh=dh)

    @pytest.mark.parametrize("fixed", [False, True])
    def test_dense_mask(self, fixed):
        """Case-I/II masks: (rows, B, 1, dh) shared across heads."""
        T, B, H, dh = 5, 2, 3, 16
        dm = (jax.random.uniform(jax.random.fold_in(KEY, 50),
                                 (1 if fixed else T, B, 1, dh)) > 0.5
              ).astype(jnp.float32)
        self._check(dict(dense_mask=dm, scale=2.0), T=T, B=B, H=H, dh=dh)

    def test_no_dropout(self):
        self._check({})

    def test_fresh_start(self):
        """Canonical (zeros, -1e30) init: the step-0 forget gate underflows
        to exactly 0 and the backward must stay finite (no inf*0)."""
        kb = self._kb(5, 16, 4, 0.5)
        self._check(dict(keep_blocks=kb, block_size=4, scale=2.0),
                    fresh=True)

    def test_bf16(self):
        kb = self._kb(4, 16, 4, 0.5)
        self._check(dict(keep_blocks=kb, block_size=4, scale=2.0),
                    T=4, B=2, H=2, dh=16, dtype=jnp.bfloat16, grads=False)

    def test_mixed_dtype_grad_dtypes(self):
        """bf16 xg with f32 states (the compute_dtype=bf16 model layout):
        every cotangent carries its primal's dtype — dxg must not widen
        to f32 through the custom_vjp."""
        T, B, H, dh = 3, 2, 2, 16
        xg = mk((T, B, H, 4 * dh), jnp.bfloat16, 41) * 0.3
        r = mk((H, dh, 4 * dh), jnp.float32, 42) * 0.2
        z = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H, dh), -1e30, jnp.float32)
        for impl in ("xla", "pallas"):
            g = jax.grad(
                lambda *a: ops.slstm_scan(*a, impl=impl)[0]
                .astype(jnp.float32).sum(), argnums=(0, 1, 2, 3))(
                    xg, r, z, z, z, m0)
            assert g[0].dtype == jnp.bfloat16, impl
            assert all(gi.dtype == jnp.float32 for gi in g[1:]), impl

    def test_per_step_masks_differ(self):
        """Each step really gathers its own kept blocks (not step 0's)."""
        T, B, H, dh, bs = 4, 2, 2, 32, 8
        args = self._setup(T, B, H, dh)
        kb = self._kb(T, dh, bs, 0.5, seed=100)
        kw = dict(block_size=bs, scale=2.0)
        for impl in ("xla", "pallas"):
            y, _ = ops.slstm_scan(*args, impl=impl, keep_blocks=kb, **kw)
            y0, _ = ops.slstm_scan(*args, impl=impl,
                                   keep_blocks=jnp.broadcast_to(
                                       kb[:1], kb.shape), **kw)
            assert not np.allclose(np.asarray(y), np.asarray(y0)), impl

    def test_stabilizer_extreme_gates(self):
        """Huge gate pre-activations must not overflow (the m stabilizer's
        whole job); h stays finite and |h| bounded by the output gate."""
        T, B, H, dh = 6, 2, 2, 8
        xg = jnp.full((T, B, H, 4 * dh), 40.0)
        r = mk((H, dh, 4 * dh), jnp.float32, 60) * 0.1
        z = jnp.zeros((B, H, dh))
        for impl in ("xla", "pallas"):
            ys, (hf, (cf, nf, mf)) = ops.slstm_scan(
                xg, r, z, z, z, jnp.full((B, H, dh), -1e30), impl=impl)
            assert bool(jnp.isfinite(ys).all()), impl
            assert float(jnp.abs(ys).max()) <= 1.0 + 1e-5, impl

    def test_both_masks_raises(self):
        args = self._setup(3, 2, 2, 16)
        kb = self._kb(3, 16, 4, 0.5)
        dm = jnp.ones((3, 2, 1, 16))
        with pytest.raises(ValueError):
            ops.slstm_scan(*args, keep_blocks=kb, dense_mask=dm,
                           block_size=4)


class TestDecoderScan:
    """Two-pass fused seq2seq decoder scan vs the per-step jnp oracle.

    The decoder's 2*nl in-scan dropout sites (input-feed NR / per-layer RH
    / upper-layer NR) are swept over mode (structured / random-dense / off,
    plus a mixed assignment) x time pattern (per-step / FIXED one-row) x
    impl (pallas interpret / xla): forward (h~ sequence + attention-scan
    finals h/c/feed) and gradients through the custom_vjp against
    autodiff-of-oracle, for every differentiable operand.
    """

    NL = 2
    DIFF = ("gx0", "us", "ws", "bs", "w_feed", "w_comb", "enc_proj",
            "enc_out", "h0", "c0", "feed0")

    def _args(self, T, B, S, H):
        G = 4 * H

        def m(shape, seed, scale=0.4):
            return mk(shape, jnp.float32, seed) * scale

        sb = jnp.where(jnp.arange(S) < S - 1, 0.0, -1e30)  # last src = pad
        return dict(
            gx0=m((T, B, G), 70),
            us=tuple(m((H, G), 71 + i) for i in range(self.NL)),
            ws=tuple(m((H, G), 74 + i) for i in range(self.NL - 1)),
            bs=tuple(m((G,), 77 + i) for i in range(self.NL - 1)),
            w_feed=m((H, G), 80),
            w_comb=m((2 * H, H), 81),
            enc_proj=m((B, S, H), 82),
            enc_out=m((B, S, H), 83),
            score_bias=jnp.broadcast_to(sb, (B, S)).astype(jnp.float32),
            h0=m((self.NL, B, H), 84, 0.5),
            c0=m((self.NL, B, H), 85, 0.5),
            feed0=m((B, H), 86, 0.5),
        )

    def _sites(self, kind, T, B, H, bs):
        sites = []
        for i in range(2 * self.NL):
            k = ("off", "sf", "sp", "dp")[i % 4] if kind == "mixed" else kind
            if k == "off":
                sites.append((None, None, 1, 1.0))
            elif k in ("sf", "sp"):           # structured, FIXED / per-step
                rows = 1 if k == "sf" else T
                kb = jnp.stack([masks.sample_keep_blocks(
                    jax.random.fold_in(KEY, 90 + 16 * i + t), H, 0.5, bs)
                    for t in range(rows)])
                sites.append((kb, None, bs, 2.0))
            else:                             # random-dense, FIXED / per-step
                rows = 1 if k == "df" else T
                dm = (jax.random.uniform(jax.random.fold_in(KEY, 60 + i),
                                         (rows, B, H)) > 0.5
                      ).astype(jnp.float32)
                sites.append((None, dm, 1, 2.0))
        return tuple(sites)

    def _check(self, kind, T=3, B=2, S=4, H=8, bs=4):
        args = self._args(T, B, S, H)
        sites = self._sites(kind, T, B, H, bs)
        wy = mk((T, B, H), jnp.float32, 87)
        wh = mk((self.NL, B, H), jnp.float32, 88)
        wf = mk((B, H), jnp.float32, 89)

        def loss(fn):
            def f(d):
                a = dict(args)
                a.update(d)
                htil, (hf, cf, ff) = fn(**a, sites=sites)
                return (jnp.sum(htil * wy) + jnp.sum(hf * wh)
                        + jnp.sum(cf) + jnp.sum(ff * wf))
            return f

        d0 = {k: args[k] for k in self.DIFF}
        y_ref = ref.decoder_scan_ref(**args, sites=sites)
        g_ref = jax.grad(loss(ref.decoder_scan_ref))(d0)
        for impl in ("xla", "pallas"):
            def fn(**kw):
                return ops.decoder_scan(**kw, impl=impl)

            y = fn(**args, sites=sites)
            np.testing.assert_allclose(y[0], y_ref[0], rtol=2e-5, atol=2e-5,
                                       err_msg=f"{kind}/{impl} h_tildes")
            for a, b, nm in zip(y[1], y_ref[1], ("h", "c", "feed")):
                np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5,
                                           err_msg=f"{kind}/{impl} {nm}_fin")
            g = jax.grad(loss(fn))(d0)
            for (p, a), (_, b) in zip(
                    jax.tree_util.tree_flatten_with_path(g)[0],
                    jax.tree_util.tree_flatten_with_path(g_ref)[0]):
                np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4,
                                           err_msg=f"{kind}/{impl} grad {p}")

    @pytest.mark.parametrize("kind", ["off", "sf", "sp", "df", "dp", "mixed"])
    def test_site_modes(self, kind):
        self._check(kind)

    def test_larger_shapes(self):
        self._check("mixed", T=5, B=3, S=6, H=16, bs=4)

    def test_structured_fixed_one_row(self):
        """A (1, nk) FIXED table == the same row broadcast to all T steps."""
        T, B, S, H, bs = 4, 2, 4, 8, 4
        args = self._args(T, B, S, H)
        kb = jnp.stack([masks.sample_keep_blocks(
            jax.random.fold_in(KEY, 200 + t), H, 0.5, bs) for t in range(T)])

        def run(impl, rows):
            sites = tuple((rows, None, bs, 2.0) for _ in range(2 * self.NL))
            return ops.decoder_scan(**args, sites=sites, impl=impl)

        for impl in ("xla", "pallas"):
            y1 = run(impl, kb[:1])
            y2 = run(impl, jnp.broadcast_to(kb[:1], (T, kb.shape[1])))
            np.testing.assert_allclose(y1[0], y2[0], rtol=1e-6, atol=1e-6,
                                       err_msg=impl)

    def test_per_step_masks_differ(self):
        """Each step really gathers its own kept blocks (not step 0's)."""
        T, B, S, H, bs = 4, 2, 4, 16, 4
        args = self._args(T, B, S, H)
        kb = jnp.stack([masks.sample_keep_blocks(
            jax.random.fold_in(KEY, 300 + t), H, 0.5, bs) for t in range(T)])

        def run(impl, rows):
            sites = ((None, None, 1, 1.0),) + tuple(
                (rows, None, bs, 2.0) for _ in range(2 * self.NL - 1))
            return ops.decoder_scan(**args, sites=sites, impl=impl)

        for impl in ("xla", "pallas"):
            y = run(impl, kb)
            y0 = run(impl, jnp.broadcast_to(kb[:1], kb.shape))
            assert not np.allclose(np.asarray(y[0]), np.asarray(y0[0])), impl

    def test_wrong_site_count_raises(self):
        args = self._args(3, 2, 4, 8)
        with pytest.raises(ValueError):
            ops.decoder_scan(**args,
                             sites=((None, None, 1, 1.0),) * (2 * self.NL - 1))


class TestLSTMPointwise:
    @pytest.mark.parametrize("B,H", [(4, 32), (8, 650), (128, 512), (3, 17)])
    @pytest.mark.parametrize("fb", [0.0, 1.0])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, H, fb, dtype):
        g, c = mk((B, 4 * H), dtype, 7), mk((B, H), dtype, 8)
        h1, c1 = ops.lstm_pointwise(g, c, forget_bias=fb)
        h2, c2 = ref.lstm_pointwise_ref(g, c, forget_bias=fb)
        np.testing.assert_allclose(np.asarray(h1, np.float32),
                                   np.asarray(h2, np.float32), **TOL[dtype])
        np.testing.assert_allclose(np.asarray(c1, np.float32),
                                   np.asarray(c2, np.float32), **TOL[dtype])

    def test_state_ranges(self):
        """sigmoid/tanh bounds: |h| <= 1 always."""
        g, c = mk((8, 256), jnp.float32, 9) * 10, mk((8, 64), jnp.float32, 10)
        h, _ = ops.lstm_pointwise(g, c)
        assert float(jnp.abs(h).max()) <= 1.0 + 1e-6


class TestKernelShardSafety:
    """Per-shard kernel calls on disjoint batch slices == the full batch.

    The shard_map data-parallel path (distributed/data_parallel.py) runs
    each fused scan on its shard's batch rows with the schedule tables
    replicated and dense masks row-sliced. That is only correct if the
    kernels carry NO cross-row state: calling them on each batch block
    independently must concatenate to the single full-batch call, forward
    AND backward (d gx blocks concatenate; dU, which every row touches,
    sums across shards because the loss is additive over rows).
    """

    def _lstm_args(self, T=5, B=8, H=16):
        gx = mk((T, B, 4 * H), jnp.float32, 401) * 0.3
        u = mk((H, 4 * H), jnp.float32, 402) * 0.1
        h0 = mk((B, H), jnp.float32, 403) * 0.5
        c0 = mk((B, H), jnp.float32, 404) * 0.5
        kb = jnp.stack([masks.sample_keep_blocks(
            jax.random.fold_in(KEY, 405 + t), H, 0.5, 4) for t in range(T)])
        dm = (jax.random.uniform(jax.random.fold_in(KEY, 406),
                                 (T, B, H)) > 0.5).astype(jnp.float32)
        lengths = jnp.array([5, 3, 0, 4, 2, 5, 1, 3], jnp.int32)
        wy = mk((T, B, H), jnp.float32, 407)
        return gx, u, h0, c0, kb, dm, lengths, wy

    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    @pytest.mark.parametrize("mode", ["structured", "dense", "ragged"])
    def test_lstm_scan_shards_concat(self, impl, mode):
        T, B, H, n_shards = 5, 8, 16, 4
        gx, u, h0, c0, kb, dm, lengths, wy = self._lstm_args(T, B, H)
        kw = dict(block_size=4, scale=2.0, impl=impl)
        if mode == "structured":
            kw["keep_blocks"] = kb            # batch-independent: replicate
        elif mode == "dense":
            kw["dense_mask"] = dm             # per-row: slice with the rows
        else:
            kw["keep_blocks"] = kb
            kw["lengths"] = lengths

        def run(gx, u, h0, c0, lo, nb):
            k = dict(kw)
            if "dense_mask" in k:
                k["dense_mask"] = jax.lax.dynamic_slice_in_dim(
                    k["dense_mask"], lo, nb, 1)
            if "lengths" in k:
                k["lengths"] = jax.lax.dynamic_slice_in_dim(
                    k["lengths"], lo, nb, 0)
            return ops.lstm_scan(gx[:, lo:lo + nb], u, h0[lo:lo + nb],
                                 c0[lo:lo + nb], **k)

        ys_full, (hf_full, cf_full) = run(gx, u, h0, c0, 0, B)
        nb = B // n_shards
        parts = [run(gx, u, h0, c0, i * nb, nb) for i in range(n_shards)]
        np.testing.assert_allclose(
            np.concatenate([np.asarray(p[0]) for p in parts], axis=1),
            np.asarray(ys_full), rtol=1e-6, atol=1e-6,
            err_msg=f"{impl}/{mode} ys")
        np.testing.assert_allclose(
            np.concatenate([np.asarray(p[1][1]) for p in parts], axis=0),
            np.asarray(cf_full), rtol=1e-6, atol=1e-6,
            err_msg=f"{impl}/{mode} c_fin")

        def loss(gx, u, h0, c0, lo, nb):
            ys, (hf, cf) = run(gx, u, h0, c0, lo, nb)
            w = jax.lax.dynamic_slice_in_dim(wy, lo, nb, 1)
            return (ys * w).sum() + (hf * cf).sum()

        gf = jax.grad(loss, argnums=(0, 1))(gx, u, h0, c0, 0, B)
        gs = [jax.grad(loss, argnums=(0, 1))(gx, u, h0, c0, i * nb, nb)
              for i in range(n_shards)]
        # d gx: each shard only touches its rows -> the blocks sum to full
        np.testing.assert_allclose(
            np.asarray(sum(g[0] for g in gs)), np.asarray(gf[0]),
            rtol=2e-5, atol=2e-5, err_msg=f"{impl}/{mode} dgx")
        # dU: every shard contributes; the psum equals the full-batch grad
        np.testing.assert_allclose(
            np.asarray(sum(g[1] for g in gs)), np.asarray(gf[1]),
            rtol=2e-5, atol=2e-5, err_msg=f"{impl}/{mode} dU")

    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    def test_decoder_scan_shards_concat(self, impl):
        """decoder_scan (attention + input feeding in-scan): disjoint
        batch-block calls — enc memory, score_bias, initial states and
        the sites' dense masks all row-sliced — concatenate to the
        full-batch call, fwd + bwd."""
        T, B, S, H, bs, NL, n_shards = 3, 4, 4, 8, 4, 2, 2
        dec = TestDecoderScan()
        args = dec._args(T, B, S, H)
        sites = dec._sites("mixed", T, B, H, bs)
        wy = mk((T, B, H), jnp.float32, 410)

        def shard_args(a, st, lo, nb):
            a = dict(a)
            for k in ("enc_proj", "enc_out", "score_bias", "feed0"):
                a[k] = a[k][lo:lo + nb]
            a["gx0"] = a["gx0"][:, lo:lo + nb]
            a["h0"] = a["h0"][:, lo:lo + nb]
            a["c0"] = a["c0"][:, lo:lo + nb]
            st = tuple((kb, None if dm is None else dm[:, lo:lo + nb], b, s)
                       for kb, dm, b, s in st)
            return a, st

        def run(a, st, lo, nb):
            a, st = shard_args(a, st, lo, nb)
            return ops.decoder_scan(**a, sites=st, impl=impl)

        y_full = run(args, sites, 0, B)
        nb = B // n_shards
        parts = [run(args, sites, i * nb, nb) for i in range(n_shards)]
        np.testing.assert_allclose(
            np.concatenate([np.asarray(p[0]) for p in parts], axis=1),
            np.asarray(y_full[0]), rtol=1e-6, atol=1e-6,
            err_msg=f"{impl} h_tildes")
        for j, nm in zip(range(3), ("h", "c", "feed")):
            ax = 0 if nm == "feed" else 1
            np.testing.assert_allclose(
                np.concatenate([np.asarray(p[1][j]) for p in parts],
                               axis=ax),
                np.asarray(y_full[1][j]), rtol=1e-6, atol=1e-6,
                err_msg=f"{impl} {nm}_fin")

        diff = ("gx0", "us", "w_feed", "w_comb")

        def loss(d, lo, nb):
            a = dict(args)
            a.update(d)
            a, st = shard_args(a, sites, lo, nb)
            htil, (hf, cf, ff) = ops.decoder_scan(**a, sites=st, impl=impl)
            w = jax.lax.dynamic_slice_in_dim(wy, lo, nb, 1)
            return (htil * w).sum() + (hf * cf).sum() + ff.sum()

        d0 = {k: args[k] for k in diff}
        gf = jax.grad(loss)(d0, 0, B)
        gs = [jax.grad(loss)(d0, i * nb, nb) for i in range(n_shards)]
        for (p, a), *rest in zip(
                jax.tree_util.tree_flatten_with_path(gf)[0],
                *(jax.tree_util.tree_flatten_with_path(g)[0] for g in gs)):
            summed = sum(np.asarray(r[1]) for r in rest)
            np.testing.assert_allclose(summed, np.asarray(a),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=f"{impl} grad {p}")
