"""Continuous-batching scheduler: invariants + end-to-end serve contract.

Unit layer (no device work): FIFO admission, slot reuse only after
eviction, duplicate-rid rejection, the "batch" policy's all-free gate,
admitted == evicted accounting.

End-to-end layer (tiny xlstm engine): under greedy decoding a request's
output depends only on its own prompt — so the same request set under two
arrival orders gives IDENTICAL per-request outputs, and the continuous
policy matches the rectangular "batch" policy token-for-token while
spending fewer device dispatches on a ragged trace (the slot refills
instead of idling until the whole group drains).
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import configs                                   # noqa: E402
from repro.configs import adapters                          # noqa: E402
from repro.distributed.sharding import strip                # noqa: E402
from repro.serving import DecodeEngine, Request, Scheduler, serve  # noqa: E402
from repro.serving.scheduler import POLICIES                # noqa: E402


@pytest.fixture(scope="module", autouse=True)
def _fresh_jit_cache():
    # this module compiles fresh decode-loop/replay executables on top of
    # everything the rest of the tier-1 suite already compiled; dropping
    # the accumulated executables first keeps the long-process footprint
    # bounded (XLA CPU was observed segfaulting on a trivial compile deep
    # into a full serial run; benchmarks/engines.py documents the same
    # long-process allocator behaviour between cells)
    jax.clear_caches()


def _req(rid, plen, max_new, vocab=64, seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    return Request(rid=rid, prompt=rng.integers(3, vocab, plen),
                   max_new=max_new)


# ---------------------------------------------------------------------------
# unit invariants (host-only)
# ---------------------------------------------------------------------------


class TestRequestValidation:
    def test_empty_prompt_rejected(self):
        with pytest.raises(ValueError, match="empty prompt"):
            Request(rid=0, prompt=np.zeros((0,), np.int32), max_new=4)

    def test_zero_budget_rejected(self):
        with pytest.raises(ValueError, match="max_new"):
            Request(rid=0, prompt=np.array([5]), max_new=0)

    def test_prompt_coerced_int32_1d(self):
        r = Request(rid=0, prompt=[[1, 2, 3]], max_new=1)
        assert r.prompt.dtype == np.int32 and r.prompt.shape == (3,)


class TestSchedulerInvariants:
    def test_unknown_policy(self):
        with pytest.raises(ValueError, match="policy"):
            Scheduler(2, policy="round-robin")
        assert POLICIES == ("continuous", "batch")

    def test_duplicate_rid_rejected(self):
        s = Scheduler(2)
        s.submit(_req(7, 3, 2))
        with pytest.raises(ValueError, match="duplicate"):
            s.submit(_req(7, 4, 2))

    def test_fifo_admission_into_free_slots(self):
        s = Scheduler(2)
        for rid in range(4):
            s.submit(_req(rid, 3, 2))
        adm = s.admit()
        assert [(slot, r.rid) for slot, r in adm] == [(0, 0), (1, 1)]
        assert s.free_slots == [] and s.busy_slots == [0, 1]
        # no free slot -> nothing admitted, queue keeps FIFO order
        assert s.admit() == []
        assert [r.rid for r in s.queue] == [2, 3]

    def test_slot_reused_only_after_eviction(self):
        s = Scheduler(1)
        s.submit(_req(0, 3, 2))
        s.submit(_req(1, 3, 2))
        (slot, r0), = s.admit()
        assert s.admit() == []          # occupied: at most one request/slot
        assert s.evict(slot) == r0.rid
        (slot2, r1), = s.admit()
        assert slot2 == slot and r1.rid == 1
        s.evict(slot2)
        with pytest.raises(ValueError, match="not busy"):
            s.evict(slot2)
        assert s.admitted == s.evicted == 2

    def test_batch_policy_waits_for_all_slots(self):
        s = Scheduler(2, policy="batch")
        for rid in range(3):
            s.submit(_req(rid, 3, 2))
        assert len(s.admit()) == 2
        s.evict(0)
        assert s.admit() == []          # one slot still busy -> no refill
        s.evict(1)
        assert [r.rid for _, r in s.admit()] == [2]

    def test_has_work(self):
        s = Scheduler(1)
        assert not s.has_work
        s.submit(_req(0, 2, 1))
        assert s.has_work
        s.admit()
        assert s.has_work               # busy slot counts as work
        s.evict(0)
        assert not s.has_work


# ---------------------------------------------------------------------------
# end-to-end serve() on a tiny recurrent engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_xlstm():
    spec = configs.get_arch("xlstm-1.3b")
    cfg = spec.smoke(num_layers=2, slstm_every=2, d_model=32, vocab=64,
                     n_heads=2)
    params = strip(adapters.init_params(spec.kind, jax.random.PRNGKey(0),
                                        cfg))
    return spec, cfg, params


def _engine(tiny_xlstm, **kw):
    spec, cfg, params = tiny_xlstm
    kw.setdefault("max_seq", 64)
    kw.setdefault("batch", 2)
    kw.setdefault("chunk", 4)
    return DecodeEngine(spec=spec, cfg=cfg, params=params,
                        temperature=0.0, **kw)


# a ragged trace: prompt lengths AND budgets staggered so eviction happens
# mid-group — the case continuous batching exists for
TRACE = [(0, 5, 4), (1, 3, 8), (2, 7, 4), (3, 2, 8), (4, 4, 4)]


def _trace_requests(order=None):
    items = TRACE if order is None else [TRACE[i] for i in order]
    return [_req(rid, plen, mnew) for rid, plen, mnew in items]


class TestServeEndToEnd:
    def test_all_requests_served_full_budget(self, tiny_xlstm):
        eng = _engine(tiny_xlstm)
        outs = serve(eng, _trace_requests())
        assert sorted(outs) == [t[0] for t in TRACE]
        for rid, _, max_new in TRACE:
            # eos disabled (eos_id=-1): every request runs to its budget
            assert len(outs[rid]) == max_new, rid
            assert outs[rid].min() >= 0

    def test_deterministic_across_arrival_orders(self, tiny_xlstm):
        eng = _engine(tiny_xlstm)
        a = serve(eng, _trace_requests())
        b = serve(eng, _trace_requests(order=[4, 2, 0, 3, 1]))
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid], err_msg=str(rid))

    def test_continuous_matches_batch_with_fewer_dispatches(self, tiny_xlstm):
        eng = _engine(tiny_xlstm)
        cont = serve(eng, _trace_requests(), policy="continuous")
        cont_chunks = eng.chunks_run
        rect = serve(eng, _trace_requests(), policy="batch")
        rect_chunks = eng.chunks_run
        for rid in cont:
            np.testing.assert_array_equal(cont[rid], rect[rid],
                                          err_msg=str(rid))
        assert cont_chunks < rect_chunks, (cont_chunks, rect_chunks)

    def test_eos_evicts_early(self, tiny_xlstm):
        # derive a real eos id from a greedy run, then re-serve with it:
        # each output must stop at (and include) its first eos occurrence
        free = serve(_engine(tiny_xlstm), _trace_requests())
        eos = int(free[0][1])           # a token greedy decoding does emit
        eng = _engine(tiny_xlstm, eos_id=eos)
        outs = serve(eng, _trace_requests())
        stopped = 0
        for rid, _, max_new in TRACE:
            o = outs[rid]
            assert len(o) <= max_new
            hits = np.nonzero(o == eos)[0]
            if hits.size:               # eos emitted -> it ends the output
                assert hits[0] == len(o) - 1, (rid, o)
                stopped += 1
            else:
                assert len(o) == max_new
        assert stopped >= 1             # the derived eos fired at least once

    def test_more_requests_than_slots_slot_reuse(self, tiny_xlstm):
        eng = _engine(tiny_xlstm, batch=2)
        reqs = [_req(rid, 2 + rid % 3, 3) for rid in range(7)]
        outs = serve(eng, reqs)
        assert len(outs) == 7
        assert all(len(v) == 3 for v in outs.values())
