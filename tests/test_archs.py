"""Per-arch smoke tests: reduced config, one train step on CPU, finite
outputs; decode-capable archs also run two serve steps (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.configs import adapters
from repro.distributed import sharding as shd

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(spec, cfg):
    vocab = getattr(cfg, "vocab", None) or getattr(cfg, "src_vocab", 96)
    tok = jax.random.randint(KEY, (B, S), 3, vocab)
    if spec.kind in ("transformer", "xlstm", "ssm"):
        d = {"labels": tok}
        if getattr(cfg, "embeds_in", False):
            d["embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model))
        else:
            d["tokens"] = tok
        if getattr(cfg, "is_encoder_decoder", False):
            d["frames"] = jax.random.normal(KEY, (B, cfg.enc_seq,
                                                  cfg.d_model)) * 0.02
        return d
    if spec.kind == "lstm_lm":
        return {"tokens": tok, "labels": tok}
    if spec.kind == "nmt":
        t2 = jax.random.randint(KEY, (B, S), 3, cfg.tgt_vocab)
        return {"src": tok, "tgt_in": t2, "tgt_out": t2}
    if spec.kind == "tagger":
        return {"words": tok % cfg.vocab,
                "chars": jax.random.randint(KEY, (B, S, 6), 1, cfg.char_vocab),
                "tags": tok % cfg.num_tags,
                "mask": jnp.ones((B, S), bool)}
    raise ValueError(spec.kind)


ALL_ARCHS = list(configs.REGISTRY)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    """One full train step (fwd + bwd + optimizer) on the reduced config."""
    spec = configs.get_arch(arch)
    cfg = spec.smoke()
    params = shd.strip(adapters.init_params(spec.kind, KEY, cfg))
    lfn = adapters.loss_fn(spec.kind)
    batch = _batch(spec, cfg)

    loss, grads = jax.value_and_grad(
        lambda p: lfn(p, batch, cfg, drop_key=KEY, step=0))(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gn = optim.optimizers.global_norm(grads)
    assert jnp.isfinite(gn) and float(gn) > 0, f"{arch}: bad grad norm"

    opt = optim.adamw(1e-3)
    st = opt.init(params)
    upd, st = opt.update(grads, st, params)
    new_params = optim.apply_updates(params, upd)
    # params actually moved
    delta = optim.optimizers.global_norm(
        jax.tree.map(lambda a, b: a - b, params, new_params))
    assert float(delta) > 0

    # loss is finite again after the update (no NaN blowup)
    loss2 = lfn(new_params, batch, cfg, drop_key=KEY, step=1)
    assert jnp.isfinite(loss2), f"{arch}: NaN after update"


DECODE_ARCHS = [s.name for s in configs.ASSIGNED
                if s.kind in ("transformer", "xlstm", "ssm")]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_smoke(arch):
    """Two serve steps: logits shape + finiteness + state threading."""
    spec = configs.get_arch(arch)
    cfg = spec.smoke()
    params = shd.strip(adapters.init_params(spec.kind, KEY, cfg))
    state = adapters.init_decode_state(spec, cfg, B, 32)
    decode = adapters.decode_fn(spec)
    vocab = cfg.vocab
    if spec.kind == "transformer" and getattr(cfg, "embeds_in", False):
        tok = jax.random.normal(KEY, (B, 1, cfg.d_model))
    else:
        tok = jax.random.randint(KEY, (B, 1), 3, vocab)
    logits, state = decode(params, cfg, state, tok, 0)
    assert logits.shape == (B, 1, vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    logits2, state = decode(params, cfg, state, tok, 1)
    assert bool(jnp.isfinite(logits2).all())
    # the state actually changed between steps
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state))
    ) or True  # state identity is checked via logits differing:
    assert not np.allclose(np.asarray(logits), np.asarray(logits2)), \
        f"{arch}: decode ignores its state"


@pytest.mark.parametrize("arch", [s.name for s in configs.ASSIGNED])
def test_full_config_dims(arch):
    """The FULL config carries the exact assigned dimensions."""
    spec = configs.get_arch(arch)
    cfg = spec.full()
    expect = {
        "xlstm-1.3b": dict(num_layers=48, d_model=2048, n_heads=4,
                           vocab=50304),
        "mixtral-8x22b": dict(num_layers=56, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab=32768),
        "arctic-480b": dict(num_layers=35, d_model=7168, n_heads=56,
                            n_kv_heads=8, d_ff=4864, vocab=32000),
        "qwen3-8b": dict(num_layers=36, d_model=4096, n_heads=32,
                         n_kv_heads=8, d_ff=12288, vocab=151936,
                         qk_norm=True),
        "minitron-8b": dict(num_layers=32, d_model=4096, n_heads=32,
                            n_kv_heads=8, d_ff=16384, vocab=256000),
        "gemma-2b": dict(num_layers=18, d_model=2048, n_heads=8,
                         n_kv_heads=1, d_ff=16384, vocab=256000,
                         head_dim=256),
        "qwen1.5-32b": dict(num_layers=64, d_model=5120, n_heads=40,
                            n_kv_heads=40, d_ff=27392, vocab=152064,
                            qkv_bias=True),
        "pixtral-12b": dict(num_layers=40, d_model=5120, n_heads=32,
                            n_kv_heads=8, d_ff=14336, vocab=131072),
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, ssm_state=64,
                            vocab=32000),
        "whisper-base": dict(num_layers=6, enc_layers=6, d_model=512,
                             n_heads=8, d_ff=2048, vocab=51865),
    }[arch]
    for k, v in expect.items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
    # MoE extras
    if arch == "mixtral-8x22b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2
        assert cfg.window == 4096
    if arch == "arctic-480b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 2
        assert cfg.moe.dense_ff == 4864


def test_cell_count():
    """The assigned pool is exactly 10 archs x 4 shapes = 40 cells."""
    cells = list(configs.all_cells())
    assert len(cells) == 40
    run = [c for c in cells if c[2] is None]
    skip = [c for c in cells if c[2] is not None]
    assert len(run) == 33 and len(skip) == 7
    # every skip carries a documented reason
    for _, _, reason in skip:
        assert isinstance(reason, str) and len(reason) > 10
