"""Serving engine: sampling, shared prefill, on-device loop, sharded state.

  * ``sample_logits`` masks top-k rejects with ``finfo.min`` of the logits
    dtype — NOT a hard-coded -1e30 — so rows whose true logits sit below
    -1e30 still sample from the real top-k (the old constant *boosted*
    masked entries above them), and the all-extreme edge stays finite.
  * ``replay_prefill`` with per-row lengths equals a dedicated replay of
    each row at its own length (ragged groups batch into ONE scan), and
    ``prompt_prefill``'s native / replay methods hand decode the same
    state (greedy continuations identical).
  * the on-device ``lax.while_loop`` chunk decode equals the per-token
    host loop token-for-token under greedy decoding, for both cache kinds
    (recurrent xlstm state and transformer KV), and exits early on
    budgets smaller than the chunk.
  * the engine runs with its state placed on a host mesh through the
    logical-axis rules; with >1 device the slot axis is really sharded.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp                                     # noqa: E402

from repro import configs                                   # noqa: E402
from repro.configs import adapters                          # noqa: E402
from repro.distributed import sharding as shd               # noqa: E402
from repro.launch import mesh as mesh_mod                   # noqa: E402
from repro.serving import (DecodeEngine, Request, prompt_prefill,  # noqa: E402
                           replay_prefill, sample_logits, serve)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module", autouse=True)
def _fresh_jit_cache():
    # see tests/test_scheduler.py: bound the long-process executable
    # footprint before compiling this module's decode loops
    jax.clear_caches()


# ---------------------------------------------------------------------------
# sample_logits (satellite: finfo.min top-k mask + sampled path)
# ---------------------------------------------------------------------------


class TestSampleLogits:
    def test_greedy_is_argmax(self):
        lg = jax.random.normal(KEY, (3, 1, 16))
        out = sample_logits(KEY, lg, temperature=0.0)
        np.testing.assert_array_equal(out[:, 0], jnp.argmax(lg[:, 0], -1))
        assert out.shape == (3, 1) and out.dtype == jnp.int32

    def test_topk_restricts_support(self):
        lg = jax.random.normal(KEY, (2, 1, 32))
        top = set(np.asarray(jax.lax.top_k(lg[:, 0], 4)[1]).ravel().tolist())
        for i in range(32):
            tok = sample_logits(jax.random.fold_in(KEY, i), lg,
                                temperature=1.0, top_k=4)
            for b in range(2):
                assert int(tok[b, 0]) in top

    def test_topk_mask_below_minus_1e30(self):
        # every real logit sits below -1e30: the old hard-coded -1e30 mask
        # RAISED rejected entries above the kept ones; finfo.min keeps the
        # true top-2 as the only support
        row = -1e32 * jnp.arange(1, 9, dtype=jnp.float32)   # descending
        lg = row[None, None, :]
        for i in range(32):
            tok = sample_logits(jax.random.fold_in(KEY, i), lg,
                                temperature=1.0, top_k=2)
            assert int(tok[0, 0]) in (0, 1)

    def test_all_extreme_edge_stays_valid(self):
        # constant row at the dtype floor: nothing is strictly below the
        # k-th value, so nothing is masked and the draw is a valid id
        lg = jnp.full((1, 1, 8), jnp.finfo(jnp.float32).min)
        tok = sample_logits(KEY, lg, temperature=1.0, top_k=3)
        assert 0 <= int(tok[0, 0]) < 8

    def test_temperature_scales_entropy(self):
        lg = jnp.array([[[0.0, 1.0, 0.0, 0.0]]])
        cold = [int(sample_logits(jax.random.fold_in(KEY, i), lg,
                                  temperature=0.05)[0, 0])
                for i in range(16)]
        assert set(cold) == {1}          # near-greedy at low temperature


# ---------------------------------------------------------------------------
# shared prefill helper
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_xlstm():
    spec = configs.get_arch("xlstm-1.3b")
    cfg = spec.smoke(num_layers=2, slstm_every=2, d_model=32, vocab=64,
                     n_heads=2)
    params = shd.strip(adapters.init_params(spec.kind, jax.random.PRNGKey(0),
                                            cfg))
    return spec, cfg, params


@pytest.fixture(scope="module")
def tiny_qwen3():
    spec = configs.get_arch("qwen3-8b")
    cfg = spec.smoke(num_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                     d_ff=64, vocab=64, max_seq=64)
    params = shd.strip(adapters.init_params(spec.kind, jax.random.PRNGKey(1),
                                            cfg))
    return spec, cfg, params


class TestReplayPrefill:
    def test_ragged_equals_dedicated_replay(self, tiny_xlstm):
        spec, cfg, params = tiny_xlstm
        B, T = 3, 6
        toks = jax.random.randint(KEY, (B, T), 3, cfg.vocab)
        lens = jnp.array([6, 4, 1], jnp.int32)
        st0 = adapters.init_decode_state(spec, cfg, B, 32)
        batched = replay_prefill(spec, cfg, params, st0, toks, lens)
        for b in range(int(B)):
            one = adapters.init_decode_state(spec, cfg, 1, 32)
            lb = int(lens[b])
            one = replay_prefill(spec, cfg, params, one, toks[b:b + 1, :lb])
            for k in batched:
                np.testing.assert_allclose(
                    np.asarray(batched[k][:, b]), np.asarray(one[k][:, 0]),
                    rtol=1e-5, atol=1e-5, err_msg=f"row {b} leaf {k}")

    def test_zero_length_replay_is_identity(self, tiny_xlstm):
        spec, cfg, params = tiny_xlstm
        st0 = adapters.init_decode_state(spec, cfg, 2, 16)
        st1 = replay_prefill(spec, cfg, params, st0,
                             jnp.zeros((2, 0), jnp.int32))
        for k in st0:
            np.testing.assert_array_equal(np.asarray(st0[k]),
                                          np.asarray(st1[k]))

    @pytest.mark.parametrize("fix", ["tiny_xlstm", "tiny_qwen3"])
    def test_native_and_replay_methods_agree(self, fix, request):
        # both prefill methods must hand decode a state that continues the
        # prompt identically (greedy)
        spec, cfg, params = request.getfixturevalue(fix)
        prompt = jax.random.randint(jax.random.fold_in(KEY, 2), (2, 7),
                                    3, cfg.vocab)
        outs = {}
        for method in ("native", "replay"):
            eng = DecodeEngine(spec=spec, cfg=cfg, params=params,
                               max_seq=32, batch=2, temperature=0.0)
            eng.state, tok0, pos0 = prompt_prefill(
                spec, cfg, params, prompt, state=eng.state, method=method)
            assert pos0 == 6
            outs[method] = eng.generate(tok0, 6, start_pos=pos0)
        np.testing.assert_array_equal(outs["native"], outs["replay"])


# ---------------------------------------------------------------------------
# on-device decode loop
# ---------------------------------------------------------------------------


class TestDeviceLoop:
    @pytest.mark.parametrize("fix", ["tiny_xlstm", "tiny_qwen3"])
    def test_matches_per_token_host_loop_greedy(self, fix, request):
        spec, cfg, params = request.getfixturevalue(fix)
        prompt = jax.random.randint(jax.random.fold_in(KEY, 3), (2, 9),
                                    3, cfg.vocab)

        def run(loop):
            eng = DecodeEngine(spec=spec, cfg=cfg, params=params,
                               max_seq=32, batch=2, temperature=0.0)
            eng.state, tok0, pos0 = prompt_prefill(
                spec, cfg, params, prompt, state=eng.state)
            gen = eng.generate if loop == "device" else eng.generate_python
            return gen(tok0, 10, start_pos=pos0)

        np.testing.assert_array_equal(run("device"), run("python"))

    def test_budget_early_exit_pads_minus_one(self, tiny_xlstm):
        spec, cfg, params = tiny_xlstm
        eng = DecodeEngine(spec=spec, cfg=cfg, params=params, max_seq=32,
                           batch=2, temperature=0.0, chunk=8)
        eng.admit([0, 1],
                  [np.array([5, 6, 7], np.int32), np.array([9], np.int32)],
                  [2, 5])
        toks, n_gen, active = eng.decode_chunk()
        np.testing.assert_array_equal(n_gen, [2, 5])
        assert not active.any()
        assert (toks[0, :2] >= 0).all() and (toks[0, 2:] == -1).all()
        assert (toks[1, :5] >= 0).all() and (toks[1, 5:] == -1).all()

    def test_admit_matches_rectangular_generate(self, tiny_xlstm):
        # one slot admitted through the scheduler path must produce the
        # same greedy tokens as the rectangular prefill+generate path
        spec, cfg, params = tiny_xlstm
        prompt = jax.random.randint(jax.random.fold_in(KEY, 4), (1, 6),
                                    3, cfg.vocab)
        eng = DecodeEngine(spec=spec, cfg=cfg, params=params, max_seq=32,
                           batch=1, temperature=0.0, chunk=8)
        eng.state, tok0, pos0 = prompt_prefill(
            spec, cfg, params, prompt, state=eng.state)
        rect = eng.generate(tok0, 8, start_pos=pos0)

        eng.reset()
        eng.admit([0], [np.asarray(prompt[0])], [8])
        toks, n_gen, _ = eng.decode_chunk(8)
        np.testing.assert_array_equal(toks, rect)
        np.testing.assert_array_equal(n_gen, [8])


class TestTransformerRectangularGuard:
    def test_ragged_admit_raises(self, tiny_qwen3):
        spec, cfg, params = tiny_qwen3
        eng = DecodeEngine(spec=spec, cfg=cfg, params=params, max_seq=32,
                           batch=2, temperature=0.0)
        with pytest.raises(NotImplementedError, match="rectangular"):
            eng.admit([0, 1], [np.array([5, 6], np.int32),
                               np.array([5], np.int32)], [4, 4])

    def test_admit_into_active_batch_raises(self, tiny_qwen3):
        spec, cfg, params = tiny_qwen3
        eng = DecodeEngine(spec=spec, cfg=cfg, params=params, max_seq=32,
                           batch=2, temperature=0.0)
        eng.admit([0], [np.array([5, 6], np.int32)], [16])
        eng.decode_chunk(2)             # slot 0 still active
        with pytest.raises(NotImplementedError, match="rectangular"):
            eng.admit([1], [np.array([5, 6], np.int32)], [4])

    def test_uniform_group_admit_works(self, tiny_qwen3):
        spec, cfg, params = tiny_qwen3
        eng = DecodeEngine(spec=spec, cfg=cfg, params=params, max_seq=32,
                           batch=2, temperature=0.0)
        outs = serve(eng, [Request(rid=0, prompt=np.array([5, 6, 7]),
                                   max_new=4),
                           Request(rid=1, prompt=np.array([8, 9, 10]),
                                   max_new=4)],
                     policy="batch")
        assert len(outs) == 2 and all(len(v) == 4 for v in outs.values())


# ---------------------------------------------------------------------------
# sharded engine state on a host mesh (CI runs this with 4 CPU devices)
# ---------------------------------------------------------------------------


class TestShardedEngine:
    def test_serve_on_host_mesh(self, tiny_xlstm):
        spec, cfg, params = tiny_xlstm
        mesh = mesh_mod.make_host_mesh()
        rules = shd.rules_for_mesh(mesh)
        n_dev = mesh.devices.size
        batch = max(4, n_dev)           # divisible by the data axis
        eng = DecodeEngine(spec=spec, cfg=cfg, params=params, max_seq=64,
                           batch=batch, rules=rules, mesh=mesh,
                           temperature=0.0, chunk=4)
        if n_dev > 1:
            # slots really shard over the data axis (axis 1 of every leaf)
            leaf = eng.state["m_C"]
            assert len(leaf.sharding.device_set) == n_dev
            spec_axes = leaf.sharding.spec
            assert "data" in str(spec_axes), spec_axes
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=rng.integers(3, cfg.vocab,
                                                   int(rng.integers(2, 8))),
                        max_new=int(rng.integers(2, 7)))
                for i in range(2 * batch + 1)]
        outs = serve(eng, reqs)
        assert len(outs) == len(reqs)
        for r in reqs:
            assert len(outs[r.rid]) == r.max_new

    def test_decode_state_shardings_cover_state(self, tiny_xlstm):
        spec, cfg, params = tiny_xlstm
        mesh = mesh_mod.make_host_mesh()
        rules = shd.rules_for_mesh(mesh)
        sh = adapters.decode_state_shardings(spec, cfg, rules, mesh,
                                             batch=4, max_seq=16)
        st = adapters.init_decode_state(spec, cfg, 4, 16)
        assert set(sh) == set(st)       # one sharding per state leaf
