"""Ragged (token-packed) batching: packed == per-sequence unpacked.

The contract this file pins down (docs/engines.md "Ragged batches"):

  * a batch with a ``lengths`` column computes the SAME loss and the SAME
    parameter gradients as running each row separately at its true length
    and token-weighted-averaging — for every recurrent engine (stepwise /
    scheduled / fused) and both fused impls (xla / pallas-interpret);
  * frozen steps repeat the last valid carry (finals = the state at each
    row's last real step, the truncated-BPTT handoff invariant);
  * ``data.pipeline.PackedBatcher`` packing is a pure function of
    (seed, epoch): restart-at-step is bit-identical, every sequence
    appears exactly once per epoch, dummy fill rows are length-0 zeros.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                     # property tests ride the importorskip convention:
    import hypothesis    # absent hypothesis skips them, never the module
    from hypothesis import given, settings
    from hypothesis import strategies as hst
except ImportError:      # pragma: no cover
    hypothesis = None

from repro.configs import adapters
from repro.configs.base import ArchSpec
from repro.core import metrics
from repro.data import pipeline, synthetic
from repro.distributed.sharding import strip
from repro.kernels.lstm_scan import lstm_scan
from repro.kernels.slstm_scan import slstm_scan
from repro.models import lstm_lm, seq2seq, tagger, xlstm

KEY = jax.random.PRNGKey(0)
ENGINES = ("stepwise", "scheduled", "fused")
IMPLS = ("xla", "pallas")        # pallas auto-interprets off TPU


def _tree_max_diff(a, b):
    return max(float(jnp.abs(x - y).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------


class TestKernelRagged:
    """lstm_scan / slstm_scan with ``lengths`` == per-row unpacked runs."""

    @pytest.mark.parametrize("impl", IMPLS)
    def test_lstm_scan_matches_per_row(self, impl):
        T, B, H = 10, 4, 16
        ks = [jax.random.fold_in(KEY, i) for i in range(4)]
        gx = jax.random.normal(ks[0], (T, B, 4 * H))
        u = jax.random.normal(ks[1], (H, 4 * H)) * 0.2
        h0 = jax.random.normal(ks[2], (B, H))
        c0 = jax.random.normal(ks[3], (B, H))
        lens = jnp.array([10, 6, 1, 8], jnp.int32)

        def packed_loss(gx, u, h0, c0):
            hs, _ = lstm_scan(gx, u, h0, c0, impl=impl, lengths=lens)
            m = metrics.length_mask(lens, T).T[:, :, None]     # (T,B,1)
            return (hs * m).sum()

        loss, grads = jax.value_and_grad(packed_loss, argnums=(0, 1, 2, 3))(
            gx, u, h0, c0)

        ref_loss, ref_gu = 0.0, jnp.zeros_like(u)
        hs_p, _ = lstm_scan(gx, u, h0, c0, impl=impl, lengths=lens)
        for b in range(B):
            L = int(lens[b])

            def row_loss(gx_b, u, h0_b, c0_b):
                hs, _ = lstm_scan(gx_b, u, h0_b, c0_b, impl=impl)
                return hs.sum()

            l, (g_gx, g_u, g_h0, g_c0) = jax.value_and_grad(
                row_loss, argnums=(0, 1, 2, 3))(
                gx[:L, b:b + 1], u, h0[b:b + 1], c0[b:b + 1])
            ref_loss += float(l)
            ref_gu = ref_gu + g_u
            np.testing.assert_allclose(grads[0][:L, b], g_gx[:, 0],
                                       atol=1e-5)
            # frozen tail steps: zero gradient into gx
            np.testing.assert_array_equal(np.asarray(grads[0][L:, b]), 0.0)
            np.testing.assert_allclose(grads[2][b], g_h0[0], atol=1e-5)
            np.testing.assert_allclose(grads[3][b], g_c0[0], atol=1e-5)
            # outputs: real prefix matches; frozen tail repeats last valid
            hs_b, (hf_b, cf_b) = lstm_scan(gx[:L, b:b + 1], u, h0[b:b + 1],
                                           c0[b:b + 1], impl=impl)
            np.testing.assert_allclose(np.asarray(hs_p[:L, b]),
                                       np.asarray(hs_b[:, 0]), atol=1e-6)
            np.testing.assert_allclose(np.asarray(hs_p[L:, b]),
                                       np.broadcast_to(hs_b[-1, 0],
                                                       (T - L, H)),
                                       atol=1e-6)
        assert abs(float(loss) - ref_loss) < 1e-4 * max(1.0, abs(ref_loss))
        np.testing.assert_allclose(np.asarray(grads[1]), np.asarray(ref_gu),
                                   atol=1e-4)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_lstm_scan_finals_are_last_valid_state(self, impl):
        T, B, H = 8, 3, 8
        gx = jax.random.normal(KEY, (T, B, 4 * H))
        u = jax.random.normal(jax.random.fold_in(KEY, 1), (H, 4 * H)) * 0.3
        h0 = jnp.zeros((B, H))
        c0 = jnp.zeros((B, H))
        lens = jnp.array([8, 3, 5], jnp.int32)
        _, (hf, cf) = lstm_scan(gx, u, h0, c0, impl=impl, lengths=lens)
        for b in range(B):
            L = int(lens[b])
            _, (hf_b, cf_b) = lstm_scan(gx[:L, b:b + 1], u, h0[b:b + 1],
                                        c0[b:b + 1], impl=impl)
            np.testing.assert_allclose(np.asarray(hf[b]),
                                       np.asarray(hf_b[0]), atol=1e-6)
            np.testing.assert_allclose(np.asarray(cf[b]),
                                       np.asarray(cf_b[0]), atol=1e-6)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_slstm_scan_matches_per_row(self, impl):
        T, B, H, dh = 7, 3, 2, 8
        ks = [jax.random.fold_in(KEY, 10 + i) for i in range(2)]
        xg = jax.random.normal(ks[0], (T, B, H, 4 * dh))
        r = jax.random.normal(ks[1], (H, dh, 4 * dh)) * 0.2
        zeros = jnp.zeros((B, H, dh))
        h0, c0, n0 = zeros, zeros, zeros
        m0 = jnp.full((B, H, dh), -1e30)
        lens = jnp.array([7, 2, 5], jnp.int32)

        def packed_loss(xg, r):
            hs, _ = slstm_scan(xg, r, h0, c0, n0, m0, impl=impl,
                               lengths=lens)
            m = metrics.length_mask(lens, T).T[:, :, None, None]
            return (hs * m).sum()

        loss, (g_xg, g_r) = jax.value_and_grad(
            packed_loss, argnums=(0, 1))(xg, r)
        ref_loss, ref_gr = 0.0, jnp.zeros_like(r)
        _, (hf, stf) = slstm_scan(xg, r, h0, c0, n0, m0, impl=impl,
                                  lengths=lens)
        for b in range(B):
            L = int(lens[b])

            def row_loss(xg_b, r):
                hs, _ = slstm_scan(xg_b, r, h0[b:b + 1], c0[b:b + 1],
                                   n0[b:b + 1], m0[b:b + 1], impl=impl)
                return hs.sum()

            l, (gx_b, gr_b) = jax.value_and_grad(
                row_loss, argnums=(0, 1))(xg[:L, b:b + 1], r)
            ref_loss += float(l)
            ref_gr = ref_gr + gr_b
            np.testing.assert_allclose(np.asarray(g_xg[:L, b]),
                                       np.asarray(gx_b[:, 0]), atol=1e-5)
            np.testing.assert_array_equal(np.asarray(g_xg[L:, b]), 0.0)
            _, (hf_b, _) = slstm_scan(xg[:L, b:b + 1], r, h0[b:b + 1],
                                      c0[b:b + 1], n0[b:b + 1],
                                      m0[b:b + 1], impl=impl)
            np.testing.assert_allclose(np.asarray(hf[b]),
                                       np.asarray(hf_b[0]), atol=1e-6)
        assert abs(float(loss) - ref_loss) < 1e-4 * max(1.0, abs(ref_loss))
        np.testing.assert_allclose(np.asarray(g_r), np.asarray(ref_gr),
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# model level: packed batch == token-weighted per-row reference
# ---------------------------------------------------------------------------


def _per_row_reference(loss_row, params, lens):
    """Token-weighted mean of per-row losses + averaged grads."""
    tot, ntok = 0.0, 0
    gref = jax.tree.map(jnp.zeros_like, params)
    for b in range(len(lens)):
        L = int(lens[b])
        if L == 0:
            continue
        l, g = jax.value_and_grad(loss_row)(params, b)
        tot += float(l)
        ntok += L
        gref = jax.tree.map(lambda a, x: a + x, gref, g)
    return tot / ntok, jax.tree.map(lambda a: a / ntok, gref)


class TestLSTMLMPacked:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_matches_per_row(self, engine):
        cfg = lstm_lm.LSTMLMConfig(vocab=40, embed=8, hidden=8,
                                   num_layers=2, engine=engine,
                                   plan=lstm_lm.DropoutPlan())
        params = lstm_lm.init_params(KEY, cfg)
        rng = np.random.default_rng(0)
        B, S = 4, 9
        toks = jnp.asarray(rng.integers(0, 40, (B, S)))
        labs = jnp.asarray(rng.integers(0, 40, (B, S)))
        lens = jnp.array([9, 4, 1, 6], jnp.int32)
        batch = {"tokens": toks, "labels": labs, "lengths": lens}
        loss, grads = jax.value_and_grad(lstm_lm.loss_fn)(params, batch, cfg)

        def row(p, b):
            L = int(lens[b])
            bb = {"tokens": toks[b:b + 1, :L], "labels": labs[b:b + 1, :L]}
            return lstm_lm.loss_fn(p, bb, cfg) * L

        ref, gref = _per_row_reference(row, params, lens)
        assert abs(float(loss) - ref) < 1e-5
        assert _tree_max_diff(grads, gref) < 1e-5

    def test_structured_dropout_case3_matches_per_row(self):
        """Case-III structured masks are batch-independent (one kept-unit
        id set per step, shared across rows), so the same drop_key gives
        each B=1 slice the identical mask sequence — packed loss must
        equal the token-weighted per-row mean under ACTIVE dropout too."""
        plan = lstm_lm.DropoutPlan.case("case3", 0.5, block_size=4,
                                        sites=("nr", "rh"))
        cfg = lstm_lm.LSTMLMConfig(vocab=40, embed=16, hidden=16,
                                   num_layers=2, engine="scheduled",
                                   plan=plan)
        params = lstm_lm.init_params(KEY, cfg)
        rng = np.random.default_rng(1)
        B, S = 3, 8
        toks = jnp.asarray(rng.integers(0, 40, (B, S)))
        labs = jnp.asarray(rng.integers(0, 40, (B, S)))
        lens = jnp.array([8, 3, 5], jnp.int32)
        dk = jax.random.PRNGKey(7)
        batch = {"tokens": toks, "labels": labs, "lengths": lens}
        loss = lstm_lm.loss_fn(params, batch, cfg, drop_key=dk, step=2)
        tot, ntok = 0.0, 0
        for b in range(B):
            L = int(lens[b])
            bb = {"tokens": toks[b:b + 1, :L], "labels": labs[b:b + 1, :L]}
            tot += float(lstm_lm.loss_fn(params, bb, cfg, drop_key=dk,
                                         step=2)) * L
            ntok += L
        assert abs(float(loss) - tot / ntok) < 1e-5

    def test_perplexity_masked(self):
        cfg = lstm_lm.LSTMLMConfig(vocab=30, embed=8, hidden=8,
                                   num_layers=1, engine="scheduled",
                                   plan=lstm_lm.DropoutPlan())
        params = lstm_lm.init_params(KEY, cfg)
        rng = np.random.default_rng(2)
        toks = jnp.asarray(rng.integers(0, 30, (2, 6)))
        labs = jnp.asarray(rng.integers(0, 30, (2, 6)))
        lens = jnp.array([6, 2], jnp.int32)
        ppl = lstm_lm.perplexity(params, toks, labs, cfg, lengths=lens)
        nll = 0.0
        for b, L in enumerate([6, 2]):
            p = lstm_lm.perplexity(params, toks[b:b + 1, :L],
                                   labs[b:b + 1, :L], cfg)
            nll += np.log(p) * L
        assert abs(ppl - np.exp(nll / 8)) < 1e-4


class TestNMTPacked:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_matches_per_row(self, engine):
        cfg = seq2seq.NMTConfig(src_vocab=30, tgt_vocab=35, embed=8,
                                hidden=8, num_layers=2, engine=engine,
                                plan=seq2seq.DropoutPlan())
        params = seq2seq.init_params(KEY, cfg)
        rng = np.random.default_rng(3)
        B, Ss, St = 3, 7, 6
        src = jnp.asarray(rng.integers(0, 30, (B, Ss)))
        tin = jnp.asarray(rng.integers(0, 35, (B, St)))
        tout = jnp.asarray(rng.integers(0, 35, (B, St)))
        sl = jnp.array([7, 3, 5], jnp.int32)
        tl = jnp.array([6, 2, 4], jnp.int32)
        batch = {"src": src, "tgt_in": tin, "tgt_out": tout,
                 "src_lengths": sl, "tgt_lengths": tl}
        loss, grads = jax.value_and_grad(seq2seq.loss_fn)(params, batch, cfg)

        def row(p, b):
            bb = {"src": src[b:b + 1, :int(sl[b])],
                  "tgt_in": tin[b:b + 1, :int(tl[b])],
                  "tgt_out": tout[b:b + 1, :int(tl[b])]}
            return seq2seq.loss_fn(p, bb, cfg) * int(tl[b])

        ref, gref = _per_row_reference(row, params, tl)
        assert abs(float(loss) - ref) < 1e-5
        assert _tree_max_diff(grads, gref) < 1e-5

    def test_encoder_finals_freeze_at_length(self):
        """The encoder state handed to the decoder is each row's state at
        its LAST REAL token, not at the padded end."""
        cfg = seq2seq.NMTConfig(src_vocab=30, tgt_vocab=30, embed=8,
                                hidden=8, num_layers=2,
                                plan=seq2seq.DropoutPlan())
        params = seq2seq.init_params(KEY, cfg)
        rng = np.random.default_rng(4)
        src = jnp.asarray(rng.integers(0, 30, (3, 9)))
        sl = jnp.array([9, 4, 6], jnp.int32)
        _, st = seq2seq.encode(params, src, cfg, lengths=sl)
        for b in range(3):
            L = int(sl[b])
            _, st_b = seq2seq.encode(params, src[b:b + 1, :L], cfg)
            np.testing.assert_allclose(np.asarray(st.h[:, b]),
                                       np.asarray(st_b.h[:, 0]), atol=1e-6)
            np.testing.assert_allclose(np.asarray(st.c[:, b]),
                                       np.asarray(st_b.c[:, 0]), atol=1e-6)


class TestTaggerPacked:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_matches_per_row_with_dummy_row(self, engine):
        """Bidirectional freeze + valid-prefix reversal + dummy (length-0)
        rows excluded from the per-sequence CRF mean."""
        cfg = tagger.TaggerConfig(vocab=50, char_vocab=20, word_embed=8,
                                  char_embed=6, char_filters=6, hidden=8,
                                  num_tags=5, engine=engine,
                                  plan=tagger.DropoutPlan())
        params = tagger.init_params(KEY, cfg)
        rng = np.random.default_rng(5)
        B, S, W = 4, 8, 5
        words = jnp.asarray(rng.integers(0, 50, (B, S)))
        chars = jnp.asarray(rng.integers(0, 20, (B, S, W)))
        tags = jnp.asarray(rng.integers(0, 5, (B, S)))
        lens = jnp.array([8, 3, 0, 5], jnp.int32)     # row 2 = dummy fill
        # zero out the dummy row the way PackedBatcher does
        words = words.at[2].set(0)
        chars = chars.at[2].set(0)
        tags = tags.at[2].set(0)
        batch = {"words": words, "chars": chars, "tags": tags,
                 "lengths": lens}
        loss, grads = jax.value_and_grad(tagger.loss_fn)(params, batch, cfg)

        tot, nreal = 0.0, 0
        gref = jax.tree.map(jnp.zeros_like, params)
        for b in range(B):
            L = int(lens[b])
            if L == 0:
                continue

            def row(p):
                bb = {"words": words[b:b + 1, :L],
                      "chars": chars[b:b + 1, :L],
                      "tags": tags[b:b + 1, :L]}
                return tagger.loss_fn(p, bb, cfg)

            l, g = jax.value_and_grad(row)(params)
            tot += float(l)
            nreal += 1
            gref = jax.tree.map(lambda a, x: a + x, gref, g)
        gref = jax.tree.map(lambda a: a / nreal, gref)
        assert abs(float(loss) - tot / nreal) < 1e-5
        assert _tree_max_diff(grads, gref) < 1e-5


class TestXLSTMPacked:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_matches_per_row(self, engine):
        cfg = xlstm.XLSTMConfig(num_layers=2, d_model=16, n_heads=2,
                                vocab=40, slstm_every=2, chunk=4,
                                engine=engine, remat="none", loss_chunks=2)
        params = strip(xlstm.init_params(KEY, cfg))
        rng = np.random.default_rng(6)
        B, S = 3, 8
        toks = jnp.asarray(rng.integers(0, 40, (B, S)))
        labs = jnp.asarray(rng.integers(0, 40, (B, S)))
        lens = jnp.array([8, 3, 6], jnp.int32)
        batch = {"tokens": toks, "labels": labs, "lengths": lens}
        loss, grads = jax.value_and_grad(xlstm.loss_fn)(params, batch, cfg)

        def row(p, b):
            L = int(lens[b])
            bb = {"tokens": toks[b:b + 1, :L], "labels": labs[b:b + 1, :L]}
            return xlstm.loss_fn(p, bb, cfg) * L

        ref, gref = _per_row_reference(row, params, lens)
        assert abs(float(loss) - ref) < 1e-5
        assert _tree_max_diff(grads, gref) < 1e-4


# ---------------------------------------------------------------------------
# property: random ragged length vectors
# ---------------------------------------------------------------------------


def _check_lengths_property(lens_list):
    cfg = lstm_lm.LSTMLMConfig(vocab=30, embed=8, hidden=8, num_layers=1,
                               engine="scheduled",
                               plan=lstm_lm.DropoutPlan())
    params = lstm_lm.init_params(KEY, cfg)
    B, S = len(lens_list), max(lens_list)
    rng = np.random.default_rng(hash(tuple(lens_list)) % (2 ** 31))
    toks = jnp.asarray(rng.integers(0, 30, (B, S)))
    labs = jnp.asarray(rng.integers(0, 30, (B, S)))
    lens = jnp.asarray(lens_list, jnp.int32)
    batch = {"tokens": toks, "labels": labs, "lengths": lens}
    loss = lstm_lm.loss_fn(params, batch, cfg)
    tot, ntok = 0.0, 0
    for b, L in enumerate(lens_list):
        bb = {"tokens": toks[b:b + 1, :L], "labels": labs[b:b + 1, :L]}
        tot += float(lstm_lm.loss_fn(params, bb, cfg)) * L
        ntok += L
    assert abs(float(loss) - tot / ntok) < 1e-5


if hypothesis is not None:
    @given(hst.lists(hst.integers(min_value=1, max_value=10), min_size=2,
                     max_size=5))
    @settings(max_examples=10, deadline=None)
    def test_ragged_lengths_property(lens_list):
        _check_lengths_property(lens_list)
else:                                                  # pragma: no cover
    @pytest.mark.parametrize("lens_list", [[5, 1], [3, 7, 2], [1, 1, 9, 4]])
    def test_ragged_lengths_property(lens_list):
        _check_lengths_property(lens_list)


# ---------------------------------------------------------------------------
# packing pipeline
# ---------------------------------------------------------------------------


class TestPacking:
    def _docs(self, n=120, max_len=32, seed=9):
        return synthetic.lm_ragged_docs(n, 50, max_len, seed=seed)

    def test_bucket_boundaries(self):
        assert pipeline.bucket_boundaries(64, 4) == (8, 16, 32, 64)
        assert pipeline.bucket_boundaries(10, 1) == (10,)

    def test_every_doc_exactly_once_per_epoch(self):
        docs = self._docs()
        pb = pipeline.PackedBatcher(docs, token_budget=256, seed=1)
        seen = []
        for cap, rows in pb._plan(0):
            assert len(rows) == max(1, 256 // cap)
            seen.extend(int(i) for i in rows if i >= 0)
        assert sorted(seen) == list(range(120))

    def test_batch_shapes_and_dummies(self):
        docs = self._docs()
        pb = pipeline.PackedBatcher(docs, token_budget=256, seed=1)
        for s in range(pb.steps_per_epoch):
            b = pb.batch_fn(s)
            B, cap = b["tokens"].shape
            assert cap in pb.boundaries
            assert B == max(1, 256 // cap)
            assert (b["lengths"] <= cap).all()
            dummy = b["lengths"] == 0
            assert (b["tokens"][dummy] == 0).all()
            assert (b["labels"][dummy] == 0).all()

    def test_restart_at_step_is_bit_identical(self):
        docs = self._docs()
        pb1 = pipeline.PackedBatcher(docs, token_budget=256, seed=2)
        pb2 = pipeline.PackedBatcher(docs, token_budget=256, seed=2)
        for s in (0, 3, pb1.steps_per_epoch + 1):      # incl. next epoch
            b1, b2 = pb1.batch_fn(s), pb2.batch_fn(s)
            assert sorted(b1) == sorted(b2)
            for k in b1:
                np.testing.assert_array_equal(b1[k], b2[k])

    def test_epochs_reshuffle(self):
        docs = self._docs()
        pb = pipeline.PackedBatcher(docs, token_budget=256, seed=3)
        p0 = [tuple(rows) for _, rows in pipeline.pack_plan(
            docs["lengths"], 256, pb.boundaries, seed=3, epoch=0)]
        p1 = [tuple(rows) for _, rows in pipeline.pack_plan(
            docs["lengths"], 256, pb.boundaries, seed=3, epoch=1)]
        assert p0 != p1

    def test_host_sharding_partitions_the_epoch(self):
        docs = self._docs()
        pbs = [pipeline.PackedBatcher(docs, token_budget=256, seed=4,
                                      host_index=h, host_count=2)
               for h in range(2)]
        assert pbs[0].steps_per_epoch == pbs[1].steps_per_epoch
        seen = []
        for pb in pbs:
            for s in range(pb.steps_per_epoch):
                epoch, idx = divmod(s, pb.steps_per_epoch)
                _, rows = pb._plan(epoch)[idx * 2 + pb.host_index]
                seen.extend(int(i) for i in rows if i >= 0)
        assert sorted(seen) == list(range(120))

    def test_rejects_overlong_sequences(self):
        with pytest.raises(ValueError):
            pipeline.pack_plan(np.array([100]), 256, (8, 16, 32, 64))

    def test_packed_batch_trains(self):
        """A PackedBatcher batch feeds lstm_lm.loss_fn as-is (the length
        column is the models' ragged opt-in) and beats rectangular slot
        utilization on a skewed corpus."""
        docs = self._docs(n=64, max_len=32)
        pb = pipeline.PackedBatcher(docs, token_budget=128, seed=5)
        cfg = lstm_lm.LSTMLMConfig(vocab=50, embed=8, hidden=8,
                                   num_layers=1, engine="scheduled",
                                   plan=lstm_lm.DropoutPlan())
        params = lstm_lm.init_params(KEY, cfg)
        b = jax.tree.map(jnp.asarray, pb.batch_fn(0))
        loss = lstm_lm.loss_fn(params, b, cfg)
        assert np.isfinite(float(loss))
        real = int(docs["lengths"].sum())
        packed_slots = sum(
            pb.batch_fn(s)["tokens"].size for s in range(pb.steps_per_epoch))
        rect_slots = -(-64 // (128 // 32)) * (128 // 32) * 32
        assert real / packed_slots > real / rect_slots

    def test_adapters_ragged_specs(self):
        from repro.configs.shapes import ShapeSpec

        def spec(kind):
            return ArchSpec(name=kind, family="rnn", kind=kind,
                            full=None, smoke=None)

        shape = ShapeSpec("s", seq_len=16, global_batch=8, kind="train")
        d = adapters.train_batch_specs(spec("lstm_lm"), None, shape,
                                       ragged=True)
        assert d["lengths"].shape == (8,)
        d = adapters.train_batch_specs(spec("nmt"), None, shape,
                                       ragged=True)
        assert "src_lengths" in d and "tgt_lengths" in d
        axes = adapters.batch_logical_axes(spec("lstm_lm"), None, shape)
        assert axes["tokens"] == ("batch", "seq")
        with pytest.raises(ValueError):
            adapters.train_batch_specs(spec("ssm"), None, shape,
                                       ragged=True)
