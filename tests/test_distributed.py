"""Sharded-vs-single-device equivalence for the training engines.

The shard_map data-parallel path (distributed/data_parallel.py wired
through launch/steps.py::make_sharded_loss_and_grad) must reproduce the
single-device loss AND gradients — allclose at f32 — for every recurrent
family x engine x dropout case, because:

  * structured keep-block tables are batch-independent: every shard
    resamples the identical table from the same site key (replication for
    free);
  * dense per-row bitmasks sample the GLOBAL mask and row-slice, so each
    shard sees bit-identical rows to the unsharded run
    (core/dropout_plan.py "Batch sharding", DropoutCtx + BatchShard);
  * losses combine as exact weighted means — psum(loss_i * w_i) /
    max(psum(w_i), 1) — so ragged batches (clamped denominators, all-pad
    shards) agree too, not just rectangular ones.

Multi-device tests take the module-scoped ``host_devices`` fixture
(conftest.py) and SKIP on a 1-device host; CI's distributed job runs them
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``. Property
tests follow the test_engine.py convention: hypothesis when installed,
a deterministic mini-grid either way.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    from hypothesis import given, settings
    from hypothesis import strategies as hst
except ImportError:      # pragma: no cover
    hypothesis = None

from repro.configs import adapters
from repro.core.dropout_plan import BatchShard, DropoutPlan
from repro.data import synthetic
from repro.distributed import data_parallel as dp
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod
from repro.models import lstm_lm, seq2seq, tagger, xlstm

KEY = jax.random.PRNGKey(0)
DROP_KEY = jax.random.PRNGKey(7)
ENGINES = ("stepwise", "scheduled", "fused")
CASES = ("case1", "case2", "case3", "case4")


def _bs(case):
    return 4 if case in ("case3", "case4") else 1


# ---------------------------------------------------------------------------
# tiny model cells (one per recurrent family)
# ---------------------------------------------------------------------------


def _lm_cell(case, engine, rate=0.5):
    plan = DropoutPlan.case(case, rate, block_size=_bs(case),
                            sites=("embed", "nr", "rh", "out"))
    cfg = lstm_lm.LSTMLMConfig(vocab=50, embed=16, hidden=16, num_layers=2,
                               plan=plan, engine=engine)
    params = lstm_lm.init_params(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (8, 6), 0, 50),
             "labels": jax.random.randint(KEY, (8, 6), 0, 50)}
    return "lstm_lm", cfg, lstm_lm.loss_fn, params, batch


def _nmt_cell(case, engine, rate=0.3):
    plan = DropoutPlan.case(case, rate, block_size=_bs(case),
                            sites=("nr", "rh", "out"))
    cfg = seq2seq.NMTConfig(src_vocab=30, tgt_vocab=30, embed=12, hidden=12,
                            num_layers=2, plan=plan, engine=engine)
    params = seq2seq.init_params(KEY, cfg)
    batch = jax.tree.map(jnp.asarray,
                         synthetic.nmt_pairs(8, 30, 30, max_len=10, seed=3))
    return "nmt", cfg, seq2seq.loss_fn, params, batch


def _tagger_cell(case, engine, rate=0.5):
    plan = DropoutPlan.case(case, rate, block_size=_bs(case),
                            sites=("inp", "rh"))
    cfg = tagger.TaggerConfig(vocab=30, char_vocab=20, hidden=16, num_tags=5,
                              word_embed=12, char_filters=8, plan=plan,
                              engine=engine)
    params = tagger.init_params(KEY, cfg)
    batch = jax.tree.map(jnp.asarray, synthetic.ner_examples(
        8, 30, 20, 5, seq=7, seed=5))
    return "tagger", cfg, tagger.loss_fn, params, batch


def _xlstm_cell(case, engine, rate=0.5):
    plan = DropoutPlan.case(case, rate, block_size=_bs(case),
                            sites=("nr", "rh"))
    cfg = xlstm.XLSTMConfig(num_layers=2, d_model=32, n_heads=4, vocab=40,
                            chunk=4, slstm_every=1, plan=plan, engine=engine)
    params = shd.strip(xlstm.init_params(KEY, cfg))
    tok = jax.random.randint(KEY, (8, 8), 0, 40)
    return "xlstm", cfg, xlstm.loss_fn, params, {"tokens": tok,
                                                 "labels": tok}


_CELLS = {"lstm_lm": _lm_cell, "nmt": _nmt_cell, "tagger": _tagger_cell,
          "xlstm": _xlstm_cell}


# ---------------------------------------------------------------------------
# the equivalence check itself
# ---------------------------------------------------------------------------


def _check_sharded(kind, cfg, lfn, params, batch, d, *, step=1,
                   rtol=5e-4, atol=1e-5):
    """Sharded (d devices) loss/grads == single-device loss/grads."""
    ref_loss, ref_grads = jax.value_and_grad(
        lambda p: lfn(p, batch, cfg, drop_key=DROP_KEY, step=step))(params)
    mesh = mesh_mod.make_data_mesh(d)
    vag = steps_mod.make_sharded_loss_and_grad(kind, cfg, mesh)
    loss, grads = jax.jit(vag)(params, batch, step, DROP_KEY)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5,
                               err_msg=f"{kind} d={d} loss")
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(grads)[0],
            jax.tree_util.tree_flatten_with_path(ref_grads)[0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol,
                                   err_msg=f"{kind} d={d} grad {path}")


def _cap(host_devices, d=4):
    return min(d, host_devices)


# ---------------------------------------------------------------------------
# engine x case matrix
# ---------------------------------------------------------------------------


class TestShardedEquivalence:
    """All four families, all three engines, sharded == single-device."""

    @pytest.mark.parametrize("case", CASES)
    @pytest.mark.parametrize("engine", ENGINES)
    def test_lstm_lm(self, host_devices, case, engine):
        _check_sharded(*_lm_cell(case, engine), _cap(host_devices))

    @pytest.mark.parametrize("case", ("case1", "case3"))
    @pytest.mark.parametrize("engine", ENGINES)
    def test_nmt(self, host_devices, case, engine):
        _check_sharded(*_nmt_cell(case, engine), _cap(host_devices))

    @pytest.mark.parametrize("case", ("case1", "case3"))
    @pytest.mark.parametrize("engine", ENGINES)
    def test_tagger(self, host_devices, case, engine):
        _check_sharded(*_tagger_cell(case, engine), _cap(host_devices))

    @pytest.mark.parametrize("case", ("case1", "case3"))
    @pytest.mark.parametrize("engine", ENGINES)
    def test_xlstm(self, host_devices, case, engine):
        _check_sharded(*_xlstm_cell(case, engine), _cap(host_devices))

    def test_fixed_time_pattern_per_family(self, host_devices):
        """case2 (RANDOM x FIXED) on the remaining families: one dense
        mask per bind, row-sliced identically on every shard + step."""
        for cell in (_nmt_cell, _tagger_cell, _xlstm_cell):
            _check_sharded(*cell("case2", "fused"), _cap(host_devices))

    def test_device_sweep_fused_case3(self, host_devices):
        """The acceptance geometry: fused engine, active case3, every
        power-of-two device count this host offers."""
        for d in (1, 2, 4, 8):
            if d <= host_devices:
                _check_sharded(*_lm_cell("case3", "fused"), d)

    def test_train_step_parity(self, host_devices):
        """One full sharded optimizer step == the unsharded train step
        (params and loss after update, not just the gradients)."""
        from repro import optim
        kind, cfg, lfn, params, batch = _lm_cell("case3", "fused")
        opt = optim.chain(optim.clip_by_global_norm(1.0), optim.adamw(1e-3))
        mesh = mesh_mod.make_data_mesh(_cap(host_devices))
        sharded = steps_mod.make_sharded_train_step(kind, cfg, opt, mesh)

        def ref_step(p, o, b, step, key):
            loss, grads = jax.value_and_grad(
                lambda q: lfn(q, b, cfg, drop_key=key, step=step))(p)
            updates, o = opt.update(grads, o, p)
            return optim.apply_updates(p, updates), o, loss

        o0 = opt.init(params)
        p_ref, _, l_ref = jax.jit(ref_step)(params, o0, batch, 1, DROP_KEY)
        p_sh, _, l_sh = jax.jit(sharded)(params, opt.init(params), batch, 1,
                                         DROP_KEY)
        np.testing.assert_allclose(float(l_sh), float(l_ref), rtol=1e-5)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-5),
            p_sh, p_ref)


class TestRaggedSharded:
    """Length-column batches: clamped masked-mean denominators, dummy
    (length-0) rows, and the in-kernel carry freeze all survive sharding."""

    def test_lstm_lm_ragged(self, host_devices):
        kind, cfg, lfn, params, batch = _lm_cell("case3", "fused")
        batch = dict(batch)
        batch["lengths"] = jnp.array([6, 3, 0, 5, 2, 6, 1, 4], jnp.int32)
        _check_sharded(kind, cfg, lfn, params, batch, _cap(host_devices))

    def test_lstm_lm_ragged_dense_case(self, host_devices):
        kind, cfg, lfn, params, batch = _lm_cell("case1", "scheduled")
        batch = dict(batch)
        batch["lengths"] = jnp.array([6, 3, 0, 5, 2, 6, 1, 4], jnp.int32)
        _check_sharded(kind, cfg, lfn, params, batch, _cap(host_devices))

    def test_nmt_ragged(self, host_devices):
        kind, cfg, lfn, params, batch = _nmt_cell("case3", "fused")
        batch = dict(batch)
        S = batch["src"].shape[1]
        batch.pop("src_mask", None)
        batch.pop("tgt_mask", None)
        batch["src_lengths"] = jnp.array([S, 4, 2, S, 5, 3, 6, 1], jnp.int32)
        batch["tgt_lengths"] = jnp.array([6, 3, 2, S, 4, 2, 5, 1], jnp.int32)
        _check_sharded(kind, cfg, lfn, params, batch, _cap(host_devices))

    def test_tagger_ragged(self, host_devices):
        kind, cfg, lfn, params, batch = _tagger_cell("case3", "fused")
        batch = dict(batch)
        lengths = jnp.array([7, 3, 0, 5, 2, 7, 1, 4], jnp.int32)
        batch["lengths"] = lengths
        batch["mask"] = (jnp.arange(7)[None, :] < lengths[:, None])
        _check_sharded(kind, cfg, lfn, params, batch, _cap(host_devices))

    def test_xlstm_ragged(self, host_devices):
        kind, cfg, lfn, params, batch = _xlstm_cell("case3", "fused")
        batch = dict(batch)
        batch["lengths"] = jnp.array([8, 3, 0, 5, 2, 8, 1, 4], jnp.int32)
        _check_sharded(kind, cfg, lfn, params, batch, _cap(host_devices))

    def test_all_pad_shard(self, host_devices):
        """A shard of nothing but dummy rows (w_i = 0) contributes zero,
        not NaN — the clamp identity l_i * w_i = masked-sum holds."""
        d = _cap(host_devices, 2)
        kind, cfg, lfn, params, batch = _lm_cell("case3", "fused")
        batch = dict(batch)
        # rows are split into d contiguous blocks; zero out the last block
        lengths = np.array([6, 3, 4, 5, 2, 6, 1, 4], np.int32)
        lengths[-(8 // d):] = 0
        batch["lengths"] = jnp.asarray(lengths)
        _check_sharded(kind, cfg, lfn, params, batch, d)


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------


class TestGuards:
    def test_non_divisible_batch_raises(self, host_devices):
        d = _cap(host_devices, 4)
        kind, cfg, lfn, params, _ = _lm_cell("case3", "fused")
        mesh = mesh_mod.make_data_mesh(d)
        vag = steps_mod.make_sharded_loss_and_grad(kind, cfg, mesh)
        bad = {"tokens": jnp.zeros((d + 1, 5), jnp.int32),
               "labels": jnp.zeros((d + 1, 5), jnp.int32)}
        with pytest.raises(ValueError, match="divisible"):
            vag(params, bad, 0, DROP_KEY)

    def test_non_divisible_batch_raises_jitted(self, host_devices):
        """The guard fires at trace time too (shapes are static), so the
        jitted path gets the same message, not an XLA reshape error."""
        d = _cap(host_devices, 4)
        kind, cfg, lfn, params, _ = _lm_cell("case3", "fused")
        mesh = mesh_mod.make_data_mesh(d)
        vag = jax.jit(steps_mod.make_sharded_loss_and_grad(kind, cfg, mesh))
        bad = {"tokens": jnp.zeros((d + 1, 5), jnp.int32),
               "labels": jnp.zeros((d + 1, 5), jnp.int32)}
        with pytest.raises(ValueError, match="divisible"):
            vag(params, bad, 0, DROP_KEY)

    def test_unsupported_kind_raises(self):
        mesh = mesh_mod.make_host_mesh()
        cfg = object()
        with pytest.raises(ValueError, match="sharded train path"):
            steps_mod.make_sharded_loss_and_grad("transformer", cfg, mesh)

    def test_loss_weight_unknown_kind(self):
        with pytest.raises(ValueError, match="sharded-loss weight"):
            adapters.loss_weight("ssm")

    def test_batch_shard_validates_count(self):
        with pytest.raises(ValueError, match="shard count"):
            BatchShard(index=0, count=0)

    def test_mesh_size_out_of_range(self):
        with pytest.raises(ValueError, match="out of range"):
            mesh_mod.make_data_mesh(len(jax.devices()) + 1)

    def test_shard_put_replicate_fallback(self, host_devices):
        """distributed/sharding.py shard_put: a param dim NOT divisible by
        its mesh axis falls back to replication instead of erroring."""
        d = _cap(host_devices, 2)
        mesh = mesh_mod.make_data_mesh(d)
        rules = shd.rules_for_mesh(mesh)
        odd = jnp.arange(d * 3 + 1, dtype=jnp.float32)[:, None] * jnp.ones(4)
        out = shd.shard_put({"w": odd}, {"w": ("batch", None)}, rules, mesh)
        # non-divisible dim 0 -> replicated spec, value untouched
        spec = out["w"].sharding.spec
        assert all(ax is None for ax in spec), spec
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(odd))
        # sanity: the divisible twin DOES shard over the data axis
        even = jnp.ones((d * 4, 4), jnp.float32)
        out2 = shd.shard_put({"w": even}, {"w": ("batch", None)}, rules, mesh)
        spec0 = out2["w"].sharding.spec[0]
        flat = spec0 if isinstance(spec0, tuple) else (spec0,)
        assert "data" in flat, out2["w"].sharding.spec

    def test_weight_matches_unsharded_denominator(self):
        """loss_weight(kind) returns exactly the weight the unsharded loss
        divides by: loss * weight is additive across row blocks."""
        for kind in adapters.SHARD_KINDS:
            _, cfg, lfn, params, batch = _CELLS[kind]("case3", "scheduled")
            w = adapters.loss_weight(kind)
            full = (float(lfn(params, batch, cfg, drop_key=None, step=0))
                    * float(w(batch, cfg)))
            B = batch["src" if kind == "nmt" else
                      "words" if kind == "tagger" else "tokens"].shape[0]
            halves = 0.0
            for lo, hi in ((0, B // 2), (B // 2, B)):
                part = {k: (v[lo:hi] if getattr(v, "ndim", 0) >= 1 else v)
                        for k, v in batch.items()}
                halves += (float(lfn(params, part, cfg, drop_key=None,
                                     step=0)) * float(w(part, cfg)))
            np.testing.assert_allclose(halves, full, rtol=1e-5)


# ---------------------------------------------------------------------------
# property tests (hypothesis + deterministic fallback, test_engine.py style)
# ---------------------------------------------------------------------------


def _check_property(d, B, T, rate, case, seed, host_devices):
    d = min(d, host_devices)
    B = B - (B % d)   # keep the draw divisible
    plan = DropoutPlan.case(case, rate, block_size=_bs(case),
                            sites=("embed", "nr", "rh", "out"))
    cfg = lstm_lm.LSTMLMConfig(vocab=40, embed=16, hidden=16, num_layers=2,
                               plan=plan, engine="fused")
    k = jax.random.PRNGKey(seed)
    params = lstm_lm.init_params(k, cfg)
    batch = {"tokens": jax.random.randint(k, (B, T), 0, 40),
             "labels": jax.random.randint(k, (B, T), 0, 40)}
    _check_sharded("lstm_lm", cfg, lstm_lm.loss_fn, params, batch, d,
                   step=seed % 5)


def test_property_grid(host_devices):
    """Deterministic mini-grid through the same check the hypothesis
    property runs (coverage even where hypothesis is not installed)."""
    _check_property(d=2, B=4, T=5, rate=0.5, case="case3", seed=11,
                    host_devices=host_devices)
    _check_property(d=4, B=8, T=3, rate=0.25, case="case1", seed=12,
                    host_devices=host_devices)
    _check_property(d=8, B=8, T=4, rate=0.65, case="case2", seed=13,
                    host_devices=host_devices)


if hypothesis is not None:
    class TestDistributedProperties:
        @settings(max_examples=6, deadline=None)
        @given(d=hst.sampled_from((1, 2, 4, 8)),
               B=hst.sampled_from((8, 16)),
               T=hst.sampled_from((2, 5)),
               rate=hst.sampled_from((0.25, 0.5, 0.65)),
               case=hst.sampled_from(CASES),
               seed=hst.integers(0, 2 ** 16))
        def test_sharded_equivalence(self, host_devices, d, B, T, rate,
                                     case, seed):
            _check_property(d, B, T, rate, case, seed, host_devices)
else:                                          # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_distributed_properties():
        pass
