"""Cross-form equivalences: the chunkwise/parallel training forms must
match the sequential decode recurrences exactly (these are the invariants
that make the serving path trustworthy)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import strip
from repro.models import ssm as M
from repro.models import transformer as T
from repro.models import xlstm as X

KEY = jax.random.PRNGKey(0)


class TestMLSTM:
    def test_chunkwise_equals_sequential(self):
        B, H, S, d = 2, 3, 16, 8
        ks = [jax.random.fold_in(KEY, i) for i in range(5)]
        q = jax.random.normal(ks[0], (B, H, S, d))
        k = jax.random.normal(ks[1], (B, H, S, d))
        v = jax.random.normal(ks[2], (B, H, S, d))
        lf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, H, S)) + 2)
        li = jax.random.normal(ks[4], (B, H, S))
        for chunk in (1, 4, 16):
            h_c, _ = X.mlstm_chunkwise(q, k, v, lf, li, chunk=chunk)
            st = (jnp.zeros((B, H, d, d)), jnp.zeros((B, H, d)),
                  jnp.full((B, H), -1e30))
            hs = []
            for t in range(S):
                h_t, st = X.mlstm_decode(q[:, :, t], k[:, :, t], v[:, :, t],
                                         lf[:, :, t], li[:, :, t], st)
                hs.append(h_t)
            np.testing.assert_allclose(h_c, jnp.stack(hs, 2), atol=2e-4,
                                       err_msg=f"chunk={chunk}")

    def test_extreme_gates_stable(self):
        """Exponential gating must not overflow with large inputs."""
        B, H, S, d = 1, 2, 8, 4
        q = k = v = jnp.ones((B, H, S, d))
        li = jnp.full((B, H, S), 50.0)        # huge log input gate
        lf = jnp.full((B, H, S), -0.01)
        h, _ = X.mlstm_chunkwise(q, k, v, lf, li, chunk=4)
        assert bool(jnp.isfinite(h).all())


class TestSSD:
    def test_chunked_equals_sequential(self):
        b, S, H, P, G, N = 2, 16, 4, 8, 1, 6
        ks = [jax.random.fold_in(KEY, i) for i in range(5)]
        x = jax.random.normal(ks[0], (b, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)))
        Bm = jax.random.normal(ks[3], (b, S, G, N))
        Cm = jax.random.normal(ks[4], (b, S, G, N))
        D = jnp.ones((H,))
        for chunk in (2, 8, 16):
            y_c, Sf = M.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
            st = jnp.zeros((b, H, P, N))
            ys = []
            for t in range(S):
                y_t, st = M.ssd_decode(x[:, t], dt[:, t], A, Bm[:, t],
                                       Cm[:, t], D, st)
                ys.append(y_t)
            np.testing.assert_allclose(y_c, jnp.stack(ys, 1), atol=2e-4)
            np.testing.assert_allclose(Sf, st, atol=2e-4)


class TestTransformerDecode:
    def test_decode_matches_forward(self):
        cfg = T.TransformerConfig(num_layers=2, d_model=32, n_heads=4,
                                  n_kv_heads=2, d_ff=64, vocab=50,
                                  q_chunk=4, kv_chunk=4, max_seq=32)
        p = strip(T.init_params(KEY, cfg))
        tk = jax.random.randint(KEY, (2, 9), 0, 50)
        logits_all = T.lm_logits(p, T.forward(p, tk, cfg), cfg)

        cache = T.init_cache(cfg, 2, 32)
        _, cache = T.prefill(p, tk[:, :4], cfg, cache)
        outs = []
        for t in range(4, 9):
            lg, cache = T.decode_step(p, cfg, cache, tk[:, t:t + 1], t)
            outs.append(lg)
        # decode logits at position t predict t+1 == forward logits at t
        for i, t in enumerate(range(4, 9)):
            np.testing.assert_allclose(outs[i][:, 0], logits_all[:, t],
                                       atol=2e-4, err_msg=f"pos {t}")

    def test_swa_matches_direct(self):
        q = jax.random.normal(KEY, (1, 16, 2, 8))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 16, 2, 8))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 16, 2, 8))
        a = T.chunked_attention(q, k, v, causal=True, window=8,
                                q_chunk=4, kv_chunk=4)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(8.0)
        idx = jnp.arange(16)
        m = (idx[:, None] >= idx[None, :]) & (idx[:, None] - idx[None, :] < 8)
        s = jnp.where(m[None, None], s, -1e30)
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
        np.testing.assert_allclose(a, ref, atol=1e-5)

    def test_gqa_grouping(self):
        """GQA must equal explicitly repeated-kv MHA."""
        q = jax.random.normal(KEY, (1, 8, 4, 8))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 8, 2, 8))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 8, 2, 8))
        a = T.chunked_attention(q, k, v, causal=True, window=None,
                                q_chunk=4, kv_chunk=4)
        kr = jnp.repeat(k, 2, axis=2)
        vr = jnp.repeat(v, 2, axis=2)
        b = T.chunked_attention(q, kr, vr, causal=True, window=None,
                                q_chunk=8, kv_chunk=8)
        np.testing.assert_allclose(a, b, atol=1e-5)


class TestZamba:
    def test_decode_matches_forward(self):
        cfg = M.Mamba2Config(num_layers=6, d_model=32, ssm_state=8,
                             n_heads=4, chunk=4, vocab=50, shared_attn=True,
                             shared_every=3, attn_heads=4, attn_kv_heads=4,
                             attn_ff=64)
        p = strip(M.init_params(KEY, cfg))
        tk = jax.random.randint(KEY, (2, 12), 0, 50)
        logits_f = M.lm_logits(p, M.forward(p, tk, cfg))
        state = M.init_state(cfg, 2, max_seq=16)
        for t in range(12):
            lg, state = M.decode_step(p, cfg, state, tk[:, t:t + 1], t)
        np.testing.assert_allclose(lg[:, 0], logits_f[:, -1], atol=1e-4)


class TestXLSTMModel:
    def test_decode_matches_forward(self):
        cfg = X.XLSTMConfig(num_layers=4, d_model=32, n_heads=4, vocab=50,
                            chunk=4, slstm_every=4)
        p = strip(X.init_params(KEY, cfg))
        tk = jax.random.randint(KEY, (2, 16), 0, 50)
        logits_f = X.lm_logits(p, X.forward(p, tk, cfg))
        state = X.init_state(cfg, 2)
        for t in range(16):
            lg, state = X.decode_step(p, cfg, state, tk[:, t:t + 1], t)
        np.testing.assert_allclose(lg[:, 0], logits_f[:, -1], atol=1e-4)

    def test_prefill_matches_forward(self):
        """prefill fills the full recurrent serving state — mLSTM (C, n, m)
        + conv ring buffer, sLSTM (h, c, n, m) stabilizer included — so
        decode from it continues the teacher-forced forward exactly."""
        cfg = X.XLSTMConfig(num_layers=4, d_model=32, n_heads=4, vocab=50,
                            chunk=4, slstm_every=4)
        p = strip(X.init_params(KEY, cfg))
        tk = jax.random.randint(KEY, (2, 11), 0, 50)
        logits_f = X.lm_logits(p, X.forward(p, tk, cfg))

        feats_p, state = X.prefill(p, tk[:, :5], cfg)
        np.testing.assert_allclose(
            X.lm_logits(p, feats_p), logits_f[:, :5], atol=1e-4)
        outs = []
        for t in range(5, 11):
            lg, state = X.decode_step(p, cfg, state, tk[:, t:t + 1], t)
            outs.append(lg)
        np.testing.assert_allclose(jnp.concatenate(outs, 1),
                                   logits_f[:, 5:11], atol=1e-4)

    def test_prefill_short_prompt_conv_pad(self):
        """Prompts shorter than conv_kernel-1 zero-pad the conv ring buffer
        (same as decode-from-scratch) instead of mis-shaping it."""
        cfg = X.XLSTMConfig(num_layers=2, d_model=32, n_heads=4, vocab=50,
                            chunk=4, slstm_every=2, conv_kernel=4)
        p = strip(X.init_params(KEY, cfg))
        tk = jax.random.randint(KEY, (2, 8), 0, 50)
        logits_f = X.lm_logits(p, X.forward(p, tk, cfg))
        _, state = X.prefill(p, tk[:, :2], cfg)       # S=2 < K-1=3
        for t in range(2, 8):
            lg, state = X.decode_step(p, cfg, state, tk[:, t:t + 1], t)
        np.testing.assert_allclose(lg[:, 0], logits_f[:, -1], atol=1e-4)
