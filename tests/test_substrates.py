"""Substrate tests: optimizers, schedules, accumulation, compression,
checkpointing (incl. crash/resume), data pipeline, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro import checkpoint as ckpt
from repro import optim
from repro.data import pipeline, synthetic
from repro.distributed import sharding as shd


class TestOptim:
    def _quad(self, opt, steps=200):
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(steps):
            grads = jax.tree.map(lambda w: 2 * w, params)  # d/dw w^2
            upd, state = opt.update(grads, state, params)
            params = optim.apply_updates(params, upd)
        return params

    def test_sgd_converges(self):
        p = self._quad(optim.sgd(0.1))
        assert float(jnp.abs(p["w"]).max()) < 1e-3

    def test_adamw_converges(self):
        p = self._quad(optim.adamw(0.1), steps=400)
        assert float(jnp.abs(p["w"]).max()) < 1e-2

    def test_clip(self):
        opt = optim.chain(optim.clip_by_global_norm(1.0), optim.sgd(1.0))
        state = opt.init({"w": jnp.zeros(3)})
        upd, _ = opt.update({"w": jnp.full(3, 100.0)}, state,
                            {"w": jnp.zeros(3)})
        assert float(jnp.linalg.norm(upd["w"])) <= 1.0 + 1e-5

    def test_nt_asgd_averaging(self):
        opt = optim.nt_asgd(0.1)
        params = {"w": jnp.array([1.0])}
        state = opt.init(params)
        for _ in range(5):
            upd, state = opt.update({"w": jnp.array([0.1])}, state, params)
            params = optim.apply_updates(params, upd)
        state = optim.optimizers.trigger_averaging(state)
        snap = params
        for _ in range(5):
            upd, state = opt.update({"w": jnp.array([0.1])}, state, params)
            params = optim.apply_updates(params, upd)
        avg = optim.optimizers.averaged_params(state, params)
        # average lies between the trigger snapshot and the final params
        assert (float(params["w"][0]) <= float(avg["w"][0])
                <= float(snap["w"][0]))

    def test_schedules(self):
        s = optim.step_decay(1.0, 0.5, every=10, start=20)
        assert float(s(0)) == 1.0 and float(s(25)) == 1.0
        assert float(s(30)) == 0.5 and float(s(40)) == 0.25
        c = optim.linear_warmup_cosine(1.0, 10, 110)
        assert float(c(5)) == pytest.approx(0.5)
        assert float(c(10)) == pytest.approx(1.0, abs=1e-6)
        assert float(c(110)) == pytest.approx(0.1, abs=1e-6)

    def test_grad_accumulation_matches_full_batch(self):
        def loss(p, b):
            return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

        key = jax.random.PRNGKey(0)
        p = {"w": jax.random.normal(key, (8, 4))}
        b = {"x": jax.random.normal(key, (16, 8)),
             "y": jax.random.normal(jax.random.fold_in(key, 1), (16, 4))}
        l1, g1 = optim.gradient_accumulation(loss, 1)(p, b)
        l4, g4 = optim.gradient_accumulation(loss, 4)(p, b)
        np.testing.assert_allclose(l1, l4, rtol=1e-5)
        np.testing.assert_allclose(g1["w"], g4["w"], rtol=1e-4, atol=1e-5)


class TestCompression:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1000), n=st.integers(10, 2000))
    def test_roundtrip_error_bounded(self, seed, n):
        x = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
        q, s, sz = optim.int8_compress(jnp.asarray(x))
        y = optim.int8_decompress(q, s, sz, x.shape)
        err = np.abs(np.asarray(y) - x)
        # per-block scale bounds error by scale/2 (round) per element
        bound = np.repeat(np.asarray(s), 256)[:n] * 0.51 + 1e-7
        assert (err <= bound).all()

    def test_stochastic_rounding_unbiased(self):
        x = jnp.full((256,), 0.3)
        acc = np.zeros(256)
        for i in range(200):
            q, s, n = optim.int8_compress(x, key=jax.random.PRNGKey(i))
            acc += np.asarray(optim.int8_decompress(q, s, n, x.shape))
        np.testing.assert_allclose(acc / 200, 0.3, atol=5e-3)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(5, dtype=jnp.float32),
                "b": {"c": jnp.ones((2, 3), jnp.bfloat16),
                      "d": jnp.array(7, jnp.int32)}}
        ckpt.save_checkpoint(str(tmp_path), 10, tree)
        restored, step = ckpt.restore_checkpoint(str(tmp_path), tree)
        assert step == 10
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert np.array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
            assert a.dtype == b.dtype

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        tree = {"a": jnp.arange(3)}
        ckpt.save_checkpoint(str(tmp_path), 1, tree)
        # simulate a crash mid-write of step 2: shard written, no manifest
        os.makedirs(tmp_path / "step_000000002")
        np.savez(tmp_path / "step_000000002" / "shard_00000_of_00001.npz",
                 a=np.zeros(3))
        assert ckpt.latest_step(str(tmp_path)) == 1
        _, step = ckpt.restore_checkpoint(str(tmp_path), tree)
        assert step == 1

    def test_gc_keeps_recent(self, tmp_path):
        tree = {"a": jnp.arange(3)}
        for s in (1, 2, 3, 4, 5):
            ckpt.save_checkpoint(str(tmp_path), s, tree, keep=2)
        steps = sorted(int(d.split("_")[1])
                       for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
        assert steps == [4, 5]


class TestData:
    def test_lm_stream_deterministic(self):
        a = synthetic.lm_stream(100, 1000, seed=3)
        b = synthetic.lm_stream(100, 1000, seed=3)
        assert np.array_equal(a, b)
        assert a.min() >= 0 and a.max() < 100

    def test_lm_stream_learnable_structure(self):
        """The Markov structure is present: bigram entropy < unigram."""
        s = synthetic.lm_stream(50, 50_000, seed=0)
        # empirical check: P(next | prev two) is peaked for the injected rule
        hits = ((s[2:] == (s[1:-1] * 31 + s[:-2] * 17 + 7) % 50).mean())
        assert hits > 0.4

    def test_nmt_pairs_shapes(self):
        d = synthetic.nmt_pairs(8, 50, 60, max_len=12)
        assert d["src"].shape == (8, 12)
        assert d["tgt_in"][:, 0].tolist() == [1] * 8   # BOS
        assert (d["src"][d["src_mask"]] >= 3).all()

    def test_ner_tags_valid_bio(self):
        d = synthetic.ner_examples(8, 100, 30, num_tags=9, seq=20)
        tags = d["tags"]
        assert tags.min() >= 0 and tags.max() < 9
        # I-x never follows O or a different entity's tag
        for i in range(8):
            for t in range(1, 20):
                cur = tags[i, t]
                if cur > 0 and cur % 2 == 0:          # I-x
                    assert tags[i, t - 1] in (cur - 1, cur)

    def test_host_shard(self):
        local, off = pipeline.host_shard(256, 3, 16)
        assert local == 16 and off == 48

    def test_sharded_batcher_prefetch(self):
        b = pipeline.ShardedBatcher(lambda step: {"x": np.full(2, step)},
                                    prefetch=2)
        b0 = next(b)
        b1 = next(b)
        assert b0["x"][0] == 0 and b1["x"][0] == 1
        b.close()


class TestShardingRules:
    def test_divisibility_guard(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = shd.rules_for_mesh(mesh)
        # both dims divisible by 1 -> sharded specs survive
        sp = shd.logical_to_pspec(("embed", "mlp"), rules, (64, 128), mesh)
        assert sp == jax.sharding.PartitionSpec("data", "model")

    def test_duplicate_axis_first_wins(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = shd.rules_for_mesh(mesh)
        sp = shd.logical_to_pspec(("mlp", "heads"), rules, (64, 64), mesh)
        assert sp == jax.sharding.PartitionSpec("model", None)

    def test_missing_mesh_axis_dropped(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = shd.rules_for_mesh(mesh)     # no "pod" axis on this mesh
        sp = shd.logical_to_pspec(("batch",), rules, (8,), mesh)
        assert sp == jax.sharding.PartitionSpec("data")

    def test_param_tagging_roundtrip(self):
        t = {"w": shd.tag(jnp.ones((2, 3)), "embed", "mlp")}
        vals, axes = shd.unzip(t)
        assert vals["w"].shape == (2, 3)
        assert axes["w"] == ("embed", "mlp")
        assert shd.strip(t)["w"].shape == (2, 3)
