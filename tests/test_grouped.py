"""grouped_matmul Pallas kernel vs jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.grouped_matmul import grouped_matmul, plan_groups

KEY = jax.random.PRNGKey(0)


def oracle(x, w, blk_expert, bm):
    T, D = x.shape
    ys = []
    for i in range(T // bm):
        e = int(blk_expert[i])
        ys.append(x[i * bm:(i + 1) * bm] @ w[e])
    return jnp.concatenate(ys, axis=0)


@pytest.mark.parametrize("T,D,F,E,bm,bf,bk", [
    (32, 16, 24, 4, 8, 8, 8),
    (64, 32, 32, 2, 16, 16, 16),
    (128, 64, 128, 8, 16, 64, 32),
    (24, 8, 8, 3, 8, 8, 8),          # one block per expert
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sweep(T, D, F, E, bm, bf, bk, dtype):
    x = jax.random.normal(KEY, (T, D), jnp.float32).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(KEY, 1), (E, D, F), jnp.float32)
         / D ** 0.5).astype(dtype)
    # expert-pure blocks: assign each row block a random expert
    blk_expert = jax.random.randint(jax.random.fold_in(KEY, 2),
                                    (T // bm,), 0, E, jnp.int32)
    y = grouped_matmul(x, w, blk_expert, bm=bm, bf=bf, bk=bk)
    y_ref = oracle(x, w, blk_expert, bm)
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else \
        dict(rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol)


def test_plan_groups_static_layout():
    counts = jnp.array([5, 0, 17, 8], jnp.int32)
    offsets, blk_expert = plan_groups(counts, bm=8, capacity_blocks=3)
    assert offsets.tolist() == [0, 24, 48, 72]
    assert blk_expert.shape == (12,)
    assert blk_expert.tolist() == [0, 0, 0, 1, 1, 1, 2, 2, 2, 3, 3, 3]


def test_matches_dense_moe_compute():
    """End-to-end: sorted buffer + grouped_matmul == per-token expert FFN."""
    T, D, F, E, bm = 32, 16, 32, 4, 8
    x = jax.random.normal(KEY, (T, D))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (E, D, F)) / D ** 0.5
    expert_of = jax.random.randint(jax.random.fold_in(KEY, 2), (T,), 0, E)
    # build an expert-sorted, block-padded buffer
    order = jnp.argsort(expert_of)
    offsets, blk_expert = plan_groups(
        jnp.bincount(expert_of, length=E), bm=bm, capacity_blocks=T // bm)
    buf = jnp.zeros((E * (T // bm) * bm, D))
    pos = {int(e): 0 for e in range(E)}
    rows = []
    for i in np.asarray(order):
        e = int(expert_of[i])
        rows.append((int(offsets[e]) + pos[e], int(i)))
        pos[e] += 1
    for dst, src in rows:
        buf = buf.at[dst].set(x[src])
    y_buf = grouped_matmul(buf, w, blk_expert, bm=bm, bf=16, bk=16)
    for dst, src in rows:
        np.testing.assert_allclose(y_buf[dst], x[src] @ w[int(expert_of[src])],
                                   rtol=1e-4, atol=1e-4)
