"""FP/BP/WG sparsity-propagation correctness (paper §3.2, Fig. 2).

The invariant: ``sdrop_matmul(x, w, keep)`` must be *numerically identical*
(up to fp32 accumulation order) to the dense reference ``(x * mask * scale) @ w``
in the forward AND in every gradient — while internally running compacted
(1-p)-sized matmuls. The gradients encode the paper's three phases:

  dy->dx  is the BP   (output column sparsity: dropped cols of dx are 0)
  (x,dy)->dW is the WG (input row sparsity: dropped rows of dW are 0)
"""
import functools

import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import masks, sparse_matmul as sm

KEY = jax.random.PRNGKey(0)


def dense_ref(x, w, kb, rate, bs, bias=None):
    scale = masks.inverted_scale(rate, w.shape[0], bs)
    m = masks.keep_blocks_to_mask(kb, w.shape[0], bs)
    y = (x * m * scale) @ w
    return y + bias if bias is not None else y


def make(B, H, N, rate, bs, seed=0, bias=False):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    x = jax.random.normal(k1, (B, H))
    w = jax.random.normal(k2, (H, N)) / np.sqrt(H)
    b = jax.random.normal(k4, (N,)) if bias else None
    kb = masks.sample_keep_blocks(k3, H, rate, bs)
    return x, w, b, kb


@pytest.mark.parametrize("B,H,N,rate,bs", [
    (4, 32, 16, 0.5, 1),
    (8, 64, 64, 0.5, 8),
    (3, 96, 40, 0.65, 1),     # odd shapes
    (16, 256, 128, 0.25, 128),
    (2, 650, 2600, 0.5, 1),   # Zaremba-medium gate matmul shape (4H out)
])
class TestForwardBackward:
    def test_forward(self, B, H, N, rate, bs):
        x, w, b, kb = make(B, H, N, rate, bs, bias=True)
        y = sm.sdrop_matmul(x, w, kb, rate=rate, block_size=bs, bias=b)
        np.testing.assert_allclose(y, dense_ref(x, w, kb, rate, bs, b),
                                   rtol=1e-5, atol=1e-5)

    def test_grads_match_dense(self, B, H, N, rate, bs):
        x, w, _, kb = make(B, H, N, rate, bs)

        def f_sd(x, w):
            return (sm.sdrop_matmul(x, w, kb, rate=rate, block_size=bs) ** 2).sum()

        def f_ref(x, w):
            return (dense_ref(x, w, kb, rate, bs) ** 2).sum()

        gs = jax.grad(f_sd, argnums=(0, 1))(x, w)
        gr = jax.grad(f_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gs[0], gr[0], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gs[1], gr[1], rtol=1e-4, atol=1e-4)

    def test_bp_output_sparsity(self, B, H, N, rate, bs):
        """Paper Fig 2(b): dropped columns of dx are exactly zero."""
        x, w, _, kb = make(B, H, N, rate, bs)
        dx = jax.grad(lambda x: sm.sdrop_matmul(
            x, w, kb, rate=rate, block_size=bs).sum())(x)
        m = np.asarray(masks.keep_blocks_to_mask(kb, H, bs))
        assert np.all(np.asarray(dx)[:, m == 0] == 0.0)

    def test_wg_row_sparsity(self, B, H, N, rate, bs):
        """Paper Fig 2(c): dropped rows of dW are exactly zero."""
        x, w, _, kb = make(B, H, N, rate, bs)
        dw = jax.grad(lambda w: sm.sdrop_matmul(
            x, w, kb, rate=rate, block_size=bs).sum(), argnums=0)(w)
        m = np.asarray(masks.keep_blocks_to_mask(kb, H, bs))
        assert np.all(np.asarray(dw)[m == 0, :] == 0.0)


class TestCompactPath:
    """x_is_compact / sdrop_matmul_out: the FFN-inner structured dropout path."""

    def test_out_then_in_equals_dense_dropout_of_inner(self):
        B, K, F, N, rate, bs = 4, 32, 64, 16, 0.5, 8
        x, w1, _, kb = make(B, K, F, rate, bs, seed=3)
        w1 = jax.random.normal(jax.random.PRNGKey(7), (K, F)) / np.sqrt(K)
        w2 = jax.random.normal(jax.random.PRNGKey(8), (F, N)) / np.sqrt(F)
        kb = masks.sample_keep_blocks(KEY, F, rate, bs)
        scale = masks.inverted_scale(rate, F, bs)

        # compact pipeline: up-proj computes only kept cols; down-proj consumes
        # compact activation with the dropout scale applied there.
        h_c = sm.sdrop_matmul_out(x, w1, kb, rate=rate, block_size=bs)
        act = jax.nn.gelu(h_c)
        y = sm.sdrop_matmul(act, w2, kb, rate=rate, block_size=bs,
                            x_is_compact=True, scale=scale)

        # dense reference: dropout(gelu(x @ w1)) @ w2
        m = masks.keep_blocks_to_mask(kb, F, bs)
        y_ref = (jax.nn.gelu(x @ w1) * m * scale) @ w2
        np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)

    def test_compact_grads(self):
        B, K, F, N, rate, bs = 4, 32, 64, 16, 0.5, 8
        w1 = jax.random.normal(jax.random.PRNGKey(7), (K, F)) / np.sqrt(K)
        w2 = jax.random.normal(jax.random.PRNGKey(8), (F, N)) / np.sqrt(F)
        x = jax.random.normal(jax.random.PRNGKey(9), (B, K))
        kb = masks.sample_keep_blocks(KEY, F, rate, bs)
        scale = masks.inverted_scale(rate, F, bs)
        m = masks.keep_blocks_to_mask(kb, F, bs)

        def f_c(x, w1, w2):
            h = sm.sdrop_matmul_out(x, w1, kb, rate=rate, block_size=bs)
            return (sm.sdrop_matmul(jax.nn.gelu(h), w2, kb, rate=rate,
                                    block_size=bs, x_is_compact=True,
                                    scale=scale) ** 2).sum()

        def f_r(x, w1, w2):
            return ((((jax.nn.gelu(x @ w1) * m * scale) @ w2)) ** 2).sum()

        gc = jax.grad(f_c, argnums=(0, 1, 2))(x, w1, w2)
        gr = jax.grad(f_r, argnums=(0, 1, 2))(x, w1, w2)
        for a, b in zip(gc, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


class TestFallbacks:
    def test_rate_zero_dense(self):
        x, w, _, _ = make(2, 16, 8, 0.5, 1)
        np.testing.assert_allclose(
            sm.sdrop_matmul(x, w, None, rate=0.0), x @ w, rtol=1e-5, atol=1e-6)

    def test_gather_scatter_roundtrip(self):
        x = jax.random.normal(KEY, (4, 64))
        kb = masks.sample_keep_blocks(KEY, 64, 0.5, 8)
        xc = sm.gather_compact(x, kb, block_size=8)
        xs = sm.scatter_compact(xc, kb, 64, block_size=8)
        m = masks.keep_blocks_to_mask(kb, 64, 8)
        np.testing.assert_allclose(xs, x * m, rtol=1e-6, atol=1e-6)

    def test_jit_static_shapes(self):
        """Compacted shapes are static under jit: one compile across mask draws."""
        x, w, _, _ = make(4, 64, 32, 0.5, 8)
        f = jax.jit(functools.partial(sm.sdrop_matmul, rate=0.5, block_size=8))
        y0 = f(x, w, masks.sample_keep_blocks(KEY, 64, 0.5, 8))
        y1 = f(x, w, masks.sample_keep_blocks(jax.random.fold_in(KEY, 1), 64, 0.5, 8))
        assert y0.shape == y1.shape == (4, 32)


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 9),
    nb=st.integers(2, 12),
    bs=st.sampled_from([1, 4, 8]),
    N=st.integers(1, 40),
    rate=st.floats(0.1, 0.8),
    seed=st.integers(0, 10_000),
)
def test_property_sdrop_equals_dense(B, nb, bs, N, rate, seed):
    """Property: forward + both grads match the dense dropout oracle for any
    shape/rate/block-size combination."""
    H = nb * bs
    x, w, _, kb = make(B, H, N, rate, bs, seed=seed)

    def f_sd(x, w):
        return (sm.sdrop_matmul(x, w, kb, rate=rate, block_size=bs) ** 2).sum()

    def f_ref(x, w):
        return (dense_ref(x, w, kb, rate, bs) ** 2).sum()

    np.testing.assert_allclose(f_sd(x, w), f_ref(x, w), rtol=1e-4, atol=1e-4)
    gs = jax.grad(f_sd, argnums=(0, 1))(x, w)
    gr = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gs[0], gr[0], rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gs[1], gr[1], rtol=1e-3, atol=1e-4)
