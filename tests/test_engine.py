"""Cross-engine equivalence: scheduled == fused == stepwise on every case.

The scheduled engine restructures execution (masks pre-sampled, NR matmuls
time-batched outside the scan, per-layer scans) and the fused engine goes
further (the whole Phase-B recurrence as one kernels/lstm_scan call per
layer, custom_vjp backward) — but all three must compute the same function.
Contract, asserted here:

  * mask schedules are BIT-identical to the stepwise per-step derivation
    (same site keys, same fold order) — for all four cases; the fused
    engine consumes the SAME ``ctx.schedule`` tables as scheduled, so this
    covers both restructured engines;
  * op-by-op (``jax.disable_jit``) scheduled and stepwise are bit-identical
    for rate 0 AND for every active case — the graphs are mathematically
    identical, so eager dispatch gives exactly equal floats (the fused
    engine reassociates the gate sum — bias folded into Phase A — so it is
    held to fp32 allclose, not bitwise);
  * jitted, outputs/grads agree across all three engines to fp32 tolerance
    (XLA fuses the graph shapes differently, so transcendental codegen may
    differ in the last bits — that is an XLA CPU property, not an engine
    property); fused grads flow through the hand-written custom_vjp;
  * FIXED time-patterns materialize ONE mask row, broadcast over steps;
  * the pallas ``impl`` (interpret mode on CPU) agrees across engines;
  * all four model families produce identical losses under every engine,
    and a jitted full train step (value_and_grad) runs finite on each arch
    under the fused engine.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                     # property tests ride the importorskip convention:
    import hypothesis    # absent hypothesis skips them, never the module
    from hypothesis import given, settings
    from hypothesis import strategies as hst
except ImportError:      # pragma: no cover
    hypothesis = None

from repro.core import lstm as lstm_mod
from repro.core import masks, sparse_matmul as sm
from repro.core.dropout_plan import DropoutPlan
from repro.data import synthetic
from repro.distributed.sharding import strip
from repro.models import lstm_lm, seq2seq, tagger, xlstm

KEY = jax.random.PRNGKey(0)
CASES = ("case1", "case2", "case3", "case4")


def _bs(case):
    return 4 if case in ("case3", "case4") else 1


def _stack_setup(num_layers=2, T=9, B=4, D=24, H=32):
    params = lstm_mod.init_lstm_params(KEY, D, H, num_layers)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (T, B, D))
    state = lstm_mod.zero_state(num_layers, B, H)
    return params, x, state


class TestScheduleMatchesStepwise:
    """ctx.schedule row t == ctx.state(..., t=t) — bit-identical masks."""

    @pytest.mark.parametrize("case", CASES)
    def test_rows_match_states(self, case):
        T, B, D = 7, 5, 32
        plan = DropoutPlan.case(case, 0.5, block_size=_bs(case),
                                sites=("nr", "rh"))
        ctx = plan.bind(jax.random.PRNGKey(3), 11)
        sched = ctx.schedule("lstm/layer0/nr", T, B, D)
        for t in range(T):
            st = ctx.state("lstm/layer0/nr", B, D, t=t)
            row = sched.state(t)
            if st.keep_blocks is not None:
                np.testing.assert_array_equal(np.asarray(st.keep_blocks),
                                              np.asarray(row.keep_blocks))
                assert st.scale == row.scale
            else:
                np.testing.assert_array_equal(np.asarray(st.dense_mask),
                                              np.asarray(row.dense_mask))

    @pytest.mark.parametrize("case", ("case2", "case4"))
    def test_fixed_materializes_one_row(self, case):
        """FIXED schedules hold ONE physical mask row, broadcast over T."""
        T, B, D = 13, 3, 32
        plan = DropoutPlan.case(case, 0.5, block_size=_bs(case),
                                sites=("nr",))
        ctx = plan.bind(jax.random.PRNGKey(1), 0)
        sched = ctx.schedule("nr", T, B, D)
        table = sched.keep_blocks if sched.structured else sched.dense_mask
        assert table.shape[0] == 1, "FIXED schedule must store a single row"
        rows = np.asarray(sched.rows())
        assert rows.shape[0] == T
        flat = rows.reshape(T, -1)
        assert np.unique(flat, axis=0).shape[0] == 1, \
            "every broadcast row must be the same mask"

    @pytest.mark.parametrize("case", ("case1", "case3"))
    def test_per_step_rows_distinct(self, case):
        T, B, D = 13, 3, 32
        plan = DropoutPlan.case(case, 0.5, block_size=_bs(case),
                                sites=("nr",))
        ctx = plan.bind(jax.random.PRNGKey(1), 0)
        rows = np.asarray(ctx.schedule("nr", T, B, D).rows()).reshape(T, -1)
        assert np.unique(rows, axis=0).shape[0] > 1, \
            "PER_STEP schedule should re-sample across steps"

    def test_inactive_schedule(self):
        ctx = DropoutPlan.off().bind(jax.random.PRNGKey(0))
        sched = ctx.schedule("nr", 5, 2, 16)
        assert sched.inactive and sched.rows() is None
        assert sched.state_for_row(None).inactive


class TestStackEquivalence:
    """2-layer lstm_stack: scheduled == stepwise."""

    def _run(self, ctx, engine, pointwise_impl="xla"):
        params, x, state = _stack_setup()
        return lstm_mod.lstm_stack(params, x, state, ctx=ctx, engine=engine,
                                   pointwise_impl=pointwise_impl)

    def test_rate0_bit_identical(self):
        """Op-by-op, the engines are exactly equal at rate 0."""
        with jax.disable_jit():
            y1, s1 = self._run(None, "stepwise")
            y2, s2 = self._run(None, "scheduled")
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
        np.testing.assert_array_equal(np.asarray(s1.h), np.asarray(s2.h))
        np.testing.assert_array_equal(np.asarray(s1.c), np.asarray(s2.c))

    @pytest.mark.parametrize("case", CASES)
    def test_active_bit_identical_opbyop(self, case):
        """Identical masks + identical math -> exactly equal, op-by-op."""
        plan = DropoutPlan.case(case, 0.5, block_size=_bs(case),
                                sites=("nr", "rh"))
        ctx = plan.bind(jax.random.PRNGKey(2), 5)
        with jax.disable_jit():
            y1, s1 = self._run(ctx, "stepwise")
            y2, s2 = self._run(ctx, "scheduled")
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2),
                                      err_msg=case)
        np.testing.assert_array_equal(np.asarray(s1.c), np.asarray(s2.c))

    @pytest.mark.parametrize("case", CASES)
    def test_active_allclose_jitted(self, case):
        """Jitted: fp32-allclose (XLA codegen may differ in the last bits)."""
        plan = DropoutPlan.case(case, 0.5, block_size=_bs(case),
                                sites=("nr", "rh"))
        ctx = plan.bind(jax.random.PRNGKey(2), 5)
        y1, s1 = self._run(ctx, "stepwise")
        y2, s2 = self._run(ctx, "scheduled")
        np.testing.assert_allclose(y1, y2, rtol=2e-5, atol=2e-5,
                                   err_msg=case)
        np.testing.assert_allclose(s1.c, s2.c, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("case", CASES)
    def test_fused_allclose_jitted(self, case):
        """Fused engine == stepwise/scheduled on every case (fwd + state)."""
        plan = DropoutPlan.case(case, 0.5, block_size=_bs(case),
                                sites=("nr", "rh"))
        ctx = plan.bind(jax.random.PRNGKey(2), 5)
        y1, s1 = self._run(ctx, "stepwise")
        y3, s3 = self._run(ctx, "fused")
        np.testing.assert_allclose(y1, y3, rtol=2e-5, atol=2e-5,
                                   err_msg=case)
        np.testing.assert_allclose(s1.c, s3.c, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(s1.h, s3.h, rtol=2e-5, atol=2e-5)

    def test_fused_rate0(self):
        y1, s1 = self._run(None, "stepwise")
        y3, s3 = self._run(None, "fused")
        np.testing.assert_allclose(y1, y3, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(s1.c, s3.c, rtol=2e-5, atol=2e-5)

    def test_grads_match(self):
        params, x, state = _stack_setup()
        plan = DropoutPlan.case("case3", 0.5, block_size=4,
                                sites=("nr", "rh"))
        ctx = plan.bind(jax.random.PRNGKey(2), 5)

        def loss(p, engine):
            ys, _ = lstm_mod.lstm_stack(p, x, state, ctx=ctx, engine=engine)
            return (ys ** 2).sum()

        g1 = jax.grad(lambda p: loss(p, "stepwise"))(params)
        g2 = jax.grad(lambda p: loss(p, "scheduled"))(params)
        for l in range(len(params)):
            for k in ("W", "U", "b"):
                np.testing.assert_allclose(g1[l][k], g2[l][k], rtol=2e-4,
                                           atol=2e-4, err_msg=f"{l}/{k}")

    @pytest.mark.parametrize("case", CASES)
    def test_fused_grads_match(self, case):
        """Grads through the fused custom_vjp == stepwise autodiff, all
        cases (W through Phase A, U/b through the reverse-time kernel, and
        the final state so dh_T/dc_T carry-in paths are exercised)."""
        params, x, state = _stack_setup()
        plan = DropoutPlan.case(case, 0.5, block_size=_bs(case),
                                sites=("nr", "rh"))
        ctx = plan.bind(jax.random.PRNGKey(2), 5)

        def loss(p, engine):
            ys, st = lstm_mod.lstm_stack(p, x, state, ctx=ctx, engine=engine)
            return (ys ** 2).sum() + (st.h ** 2).sum() + (st.c ** 2).sum()

        g1 = jax.grad(lambda p: loss(p, "stepwise"))(params)
        g3 = jax.grad(lambda p: loss(p, "fused"))(params)
        for l in range(len(params)):
            for k in ("W", "U", "b"):
                np.testing.assert_allclose(
                    g1[l][k], g3[l][k], rtol=2e-4, atol=2e-4,
                    err_msg=f"{case} {l}/{k}")

    def test_pallas_impl_equivalent(self):
        """pallas sdrop impl (interpret=True on CPU) agrees across engines."""
        plan = DropoutPlan.case("case3", 0.5, block_size=8, impl="pallas",
                                sites=("nr", "rh"))
        ctx = plan.bind(jax.random.PRNGKey(3), 1)
        y1, _ = self._run(ctx, "stepwise")
        y2, _ = self._run(ctx, "scheduled")
        np.testing.assert_allclose(y1, y2, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("case", ("case1", "case3"))
    def test_fused_pallas_impl_equivalent(self, case):
        """impl="pallas" routes fused through the persistent-scan Pallas
        kernel (interpret mode on CPU) — fwd and grads agree with xla."""
        params, x, state = _stack_setup()
        bs = _bs(case) * 2
        ctxs = {impl: DropoutPlan.case(case, 0.5, block_size=bs, impl=impl,
                                       sites=("nr", "rh"))
                .bind(jax.random.PRNGKey(3), 1) for impl in ("pallas", "xla")}
        y_p, _ = self._run(ctxs["pallas"], "fused")    # persistent kernel
        y_x, _ = self._run(ctxs["xla"], "fused")
        np.testing.assert_allclose(y_p, y_x, rtol=2e-5, atol=2e-5)

        def loss(p, c):
            ys, _ = lstm_mod.lstm_stack(p, x, state, ctx=c, engine="fused")
            return (ys ** 2).sum()

        gp = jax.grad(lambda p: loss(p, ctxs["pallas"]))(params)
        gx = jax.grad(lambda p: loss(p, ctxs["xla"]))(params)
        for l in range(len(params)):
            for k in ("W", "U", "b"):
                np.testing.assert_allclose(gp[l][k], gx[l][k], rtol=2e-4,
                                           atol=2e-4, err_msg=f"{l}/{k}")

    def test_fused_fixed_one_row(self):
        """FIXED (case4) schedules reach the fused kernel as ONE-row tables
        and still match a stepwise run that re-derives the mask per step."""
        plan = DropoutPlan.case("case4", 0.5, block_size=4,
                                sites=("nr", "rh"))
        ctx = plan.bind(jax.random.PRNGKey(7), 3)
        sched = ctx.schedule("lstm/layer0/rh", 9, 4, 32)
        assert sched.keep_blocks.shape[0] == 1
        y1, _ = self._run(ctx, "stepwise")
        y3, _ = self._run(ctx, "fused")
        np.testing.assert_allclose(y1, y3, rtol=2e-5, atol=2e-5)

    def test_unknown_engine_raises(self):
        params, x, state = _stack_setup()
        with pytest.raises(ValueError):
            lstm_mod.lstm_stack(params, x, state, engine="warp")


class TestScheduledMatmul:
    """sdrop_matmul_scheduled == per-step sdrop_matmul loop (fwd + grads)."""

    def setup_method(self, _):
        T, B, H, N, bs, rate = 6, 4, 48, 20, 4, 0.5
        self.rate, self.bs = rate, bs
        self.kb = jax.vmap(lambda k: masks.sample_keep_blocks(
            k, H, rate, bs))(jax.random.split(KEY, T))
        self.x = jax.random.normal(KEY, (T, B, H))
        self.w = jax.random.normal(jax.random.fold_in(KEY, 1), (H, N)) / 7.0

    def _per_step(self, x, w):
        return jnp.stack([sm.sdrop_matmul(x[t], w, self.kb[t],
                                          rate=self.rate, block_size=self.bs)
                          for t in range(x.shape[0])])

    @pytest.mark.parametrize("impl", ("xla", "pallas"))
    def test_forward_and_grads(self, impl):
        def f(x, w):
            return (sm.sdrop_matmul_scheduled(
                x, w, self.kb, rate=self.rate, block_size=self.bs,
                impl=impl) ** 2).sum()

        def f_ref(x, w):
            return (self._per_step(x, w) ** 2).sum()

        np.testing.assert_allclose(f(self.x, self.w), f_ref(self.x, self.w),
                                   rtol=1e-5)
        g = jax.grad(f, argnums=(0, 1))(self.x, self.w)
        gr = jax.grad(f_ref, argnums=(0, 1))(self.x, self.w)
        np.testing.assert_allclose(g[0], gr[0], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(g[1], gr[1], rtol=1e-4, atol=1e-4)

    def test_bp_sparsity_structure(self):
        """Dropped columns of δx are exactly zero at each step."""
        g = jax.grad(lambda x: (sm.sdrop_matmul_scheduled(
            x, self.w, self.kb, rate=self.rate,
            block_size=self.bs) ** 2).sum())(self.x)
        for t in range(self.x.shape[0]):
            ids = masks.keep_blocks_to_unit_ids(self.kb[t], self.bs)
            kept = np.zeros(self.x.shape[-1], bool)
            kept[np.asarray(ids)] = True
            assert np.all(np.asarray(g[t][:, ~kept]) == 0), f"step {t}"

    def test_fixed_row_delegates(self):
        y1 = sm.sdrop_matmul_scheduled(self.x, self.w, self.kb[:1],
                                       rate=self.rate, block_size=self.bs)
        y2 = sm.sdrop_matmul(self.x, self.w, self.kb[0], rate=self.rate,
                             block_size=self.bs)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


class TestModelEquivalence:
    """Same loss from all three engines on every recurrent model family."""

    def test_lstm_lm(self):
        plan = DropoutPlan.case("case3", 0.5, block_size=4,
                                sites=("embed", "nr", "rh", "out"))
        batch = {"tokens": jax.random.randint(KEY, (4, 12), 0, 100),
                 "labels": jax.random.randint(KEY, (4, 12), 0, 100)}
        losses = []
        for e in ("stepwise", "scheduled", "fused"):
            cfg = lstm_lm.LSTMLMConfig(vocab=100, embed=32, hidden=32,
                                       num_layers=2, plan=plan, engine=e)
            p = lstm_lm.init_params(KEY, cfg)
            losses.append(float(lstm_lm.loss_fn(
                p, batch, cfg, drop_key=jax.random.PRNGKey(1), step=2)))
        np.testing.assert_allclose(losses[1:], [losses[0]] * 2,
                                   rtol=1e-5)

    def test_nmt(self):
        plan = DropoutPlan.case("case3", 0.3, block_size=4,
                                sites=("nr", "rh", "out"))
        b = jax.tree.map(jnp.asarray,
                         synthetic.nmt_pairs(4, 60, 60, max_len=10, seed=3))
        losses = []
        for e in ("stepwise", "scheduled", "fused"):
            cfg = seq2seq.NMTConfig(src_vocab=60, tgt_vocab=60, embed=24,
                                    hidden=24, num_layers=2, plan=plan,
                                    engine=e)
            p = seq2seq.init_params(KEY, cfg)
            losses.append(float(seq2seq.loss_fn(
                p, b, cfg, drop_key=jax.random.PRNGKey(4), step=1)))
        np.testing.assert_allclose(losses[1:], [losses[0]] * 2,
                                   rtol=1e-5)

    def test_tagger(self):
        plan = DropoutPlan.case("case3", 0.5, block_size=4,
                                sites=("inp", "rh"))
        b = jax.tree.map(jnp.asarray, synthetic.ner_examples(
            4, 80, 30, 5, seq=10, seed=5))
        losses = []
        for e in ("stepwise", "scheduled", "fused"):
            cfg = tagger.TaggerConfig(vocab=80, char_vocab=30, hidden=32,
                                      num_tags=5, word_embed=20,
                                      char_filters=12, plan=plan, engine=e)
            p = tagger.init_params(KEY, cfg)
            losses.append(float(tagger.loss_fn(
                p, b, cfg, drop_key=jax.random.PRNGKey(6), step=1)))
        np.testing.assert_allclose(losses[1:], [losses[0]] * 2,
                                   rtol=1e-5)

    def test_xlstm(self):
        plan = DropoutPlan.case("case3", 0.5, block_size=4,
                                sites=("nr", "rh"))
        tok = jax.random.randint(KEY, (2, 16), 0, 50)
        losses = []
        for e in ("stepwise", "scheduled", "fused"):
            cfg = xlstm.XLSTMConfig(num_layers=4, d_model=32, n_heads=4,
                                    vocab=50, chunk=4, slstm_every=4,
                                    plan=plan, engine=e)
            p = strip(xlstm.init_params(KEY, cfg))
            losses.append(float(xlstm.loss_fn(
                p, {"tokens": tok, "labels": tok}, cfg,
                drop_key=jax.random.PRNGKey(8), step=0)))
        np.testing.assert_allclose(losses[1:], [losses[0]] * 2,
                                   rtol=1e-5)


class TestFusedTrainStep:
    """Jitted full train step (value_and_grad through the fused custom_vjp)
    runs and yields finite loss/grads on every recurrent arch."""

    def _smoke(self, kind, cfg, batch):
        from repro.configs import adapters
        from repro.distributed.sharding import strip as _strip

        lfn = adapters.loss_fn(kind)
        params = _strip(adapters.init_params(kind, KEY, cfg))

        @jax.jit
        def step(p, b):
            return jax.value_and_grad(
                lambda q: lfn(q, b, cfg, drop_key=jax.random.PRNGKey(5),
                              step=1))(p)

        loss, grads = step(params, jax.tree.map(jnp.asarray, batch))
        assert np.isfinite(float(loss)), kind
        leaves = jax.tree.leaves(grads)
        assert leaves and all(np.all(np.isfinite(np.asarray(g)))
                              for g in leaves), kind

    def test_lstm_lm(self):
        plan = DropoutPlan.case("case3", 0.5, block_size=4,
                                sites=("embed", "nr", "rh", "out"))
        cfg = lstm_lm.LSTMLMConfig(vocab=100, embed=32, hidden=32,
                                   num_layers=2, plan=plan, engine="fused")
        self._smoke("lstm_lm", cfg,
                    {"tokens": jax.random.randint(KEY, (4, 12), 0, 100),
                     "labels": jax.random.randint(KEY, (4, 12), 0, 100)})

    def test_nmt(self):
        plan = DropoutPlan.case("case3", 0.3, block_size=4,
                                sites=("nr", "rh", "out"))
        cfg = seq2seq.NMTConfig(src_vocab=60, tgt_vocab=60, embed=24,
                                hidden=24, num_layers=2, plan=plan,
                                engine="fused")
        self._smoke("nmt", cfg, synthetic.nmt_pairs(4, 60, 60, max_len=10,
                                                    seed=3))

    def test_tagger(self):
        plan = DropoutPlan.case("case3", 0.5, block_size=4,
                                sites=("inp", "rh"))
        cfg = tagger.TaggerConfig(vocab=80, char_vocab=30, hidden=32,
                                  num_tags=5, word_embed=20,
                                  char_filters=12, plan=plan, engine="fused")
        self._smoke("tagger", cfg, synthetic.ner_examples(4, 80, 30, 5,
                                                          seq=10, seed=5))

    def test_xlstm(self):
        plan = DropoutPlan.case("case3", 0.5, block_size=4,
                                sites=("nr", "rh"))
        cfg = xlstm.XLSTMConfig(num_layers=4, d_model=32, n_heads=4,
                                vocab=50, chunk=4, slstm_every=4, plan=plan,
                                engine="fused")
        tok = jax.random.randint(KEY, (2, 16), 0, 50)
        self._smoke("xlstm", cfg, {"tokens": tok, "labels": tok})


class TestSLSTMBlockEquivalence:
    """xLSTM sLSTM block: the fused kernels/slstm_scan path == the
    scheduled/stepwise scans, forward AND gradients, on every case —
    the stabilizer (m), normalizer (n) and per-head block-diagonal R all
    ride through the cell-parametric fused machinery."""

    def _setup(self, heads=4, dh=8, B=3, S=9):
        cfg = xlstm.XLSTMConfig(num_layers=1, d_model=heads * dh,
                                n_heads=heads, slstm_every=1)
        sl = jax.tree.map(lambda a: a[0],
                          strip(xlstm.init_slstm_block(KEY, cfg, 1)))
        x = jax.random.normal(jax.random.fold_in(KEY, 77),
                              (B, S, cfg.d_model)) * 0.5
        return cfg, sl, x

    def _run(self, cfg, sl, x, ctx, engine):
        cfg_e = dataclasses.replace(cfg, engine=engine)
        y, (hf, stf) = xlstm.slstm_block_apply(sl, x, cfg_e, ctx=ctx,
                                               rh_site="rh")
        return y, hf, stf

    @pytest.mark.parametrize("case", CASES)
    def test_forward_and_state(self, case):
        cfg, sl, x = self._setup()
        plan = DropoutPlan.case(case, 0.5, block_size=_bs(case),
                                sites=("rh",))
        ctx = plan.bind(jax.random.PRNGKey(5), 3)
        y1, h1, st1 = self._run(cfg, sl, x, ctx, "stepwise")
        for e in ("scheduled", "fused"):
            y, h, st = self._run(cfg, sl, x, ctx, e)
            np.testing.assert_allclose(y, y1, rtol=2e-5, atol=2e-5,
                                       err_msg=f"{case} {e}")
            np.testing.assert_allclose(h, h1, rtol=2e-5, atol=2e-5)
            for a, b, nm in zip(st, st1, ("c", "n", "m")):
                np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5,
                                           err_msg=f"{case} {e} {nm}")

    @pytest.mark.parametrize("case", CASES)
    def test_grads_match(self, case):
        """d loss / d {R, w_gates, ...} through the fused custom_vjp ==
        stepwise autodiff (the x@W path, the recurrence, and the final
        (h, c, n, m) carry-out cotangents are all exercised)."""
        cfg, sl, x = self._setup(S=7)
        plan = DropoutPlan.case(case, 0.5, block_size=_bs(case),
                                sites=("rh",))
        ctx = plan.bind(jax.random.PRNGKey(5), 3)

        def loss(p, engine):
            y, hf, stf = self._run(cfg, p, x, ctx, engine)
            return (y ** 2).sum() + (hf ** 2).sum() + (stf[0] ** 2).sum()

        g1 = jax.grad(lambda p: loss(p, "stepwise"))(sl)
        g3 = jax.grad(lambda p: loss(p, "fused"))(sl)
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(g1)[0],
                jax.tree_util.tree_flatten_with_path(g3)[0]):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4,
                                       err_msg=f"{case} {path}")

    def test_fused_pallas_impl_equivalent(self):
        """impl="pallas" routes the sLSTM block through the persistent-scan
        kernel (interpret mode on CPU) and agrees with xla."""
        cfg, sl, x = self._setup()
        ys = {}
        for impl in ("pallas", "xla"):
            plan = DropoutPlan.case("case3", 0.5, block_size=4, impl=impl,
                                    sites=("rh",))
            ctx = plan.bind(jax.random.PRNGKey(6), 1)
            ys[impl], _, _ = self._run(cfg, sl, x, ctx, "fused")
        np.testing.assert_allclose(ys["pallas"], ys["xla"], rtol=2e-5,
                                   atol=2e-5)

    def test_eval_mode_fused(self):
        """No dropout (eval ctx): fused still runs the kernel and matches."""
        cfg, sl, x = self._setup()
        y1, h1, st1 = self._run(cfg, sl, x, None, "stepwise")
        y3, h3, st3 = self._run(cfg, sl, x, None, "fused")
        np.testing.assert_allclose(y1, y3, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(st1[2], st3[2], rtol=2e-5, atol=2e-5)


class TestFusedServingHandoff:
    """Serving regression: params trained under engine="fused" must hand
    off cleanly to serving/engine.py's recurrent prefill -> step path —
    the prefill state (sLSTM (h, c, n, m) stabilizer included, mLSTM
    (C, n, m) + conv tail) feeds decode_step and yields deterministic,
    finite generations."""

    def _train_fused(self, cfg, steps=3):
        from repro.configs import adapters
        lfn = adapters.loss_fn("xlstm")
        params = strip(adapters.init_params("xlstm", KEY, cfg))
        tok = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 13),
                                 0, cfg.vocab)
        batch = {"tokens": tok[:, :-1], "labels": tok[:, 1:]}

        @jax.jit
        def step(p, i):
            l, g = jax.value_and_grad(lambda q: lfn(
                q, batch, cfg, drop_key=jax.random.fold_in(KEY, 100 + i),
                step=i))(p)
            return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), l
        for i in range(steps):
            params, loss = step(params, jnp.int32(i))
        assert bool(jnp.isfinite(loss)), "fused training diverged"
        return params

    def test_prefill_step_deterministic_finite(self):
        from repro.configs import xlstm_1_3b
        from repro.serving.engine import DecodeEngine
        spec = xlstm_1_3b.SPEC
        cfg = spec.smoke(engine="fused", num_layers=4, slstm_every=2)
        params = self._train_fused(cfg)

        prompt = jax.random.randint(jax.random.fold_in(KEY, 2), (2, 6),
                                    0, cfg.vocab)
        outs = []
        for _ in range(2):                 # same prompt twice: deterministic
            eng = DecodeEngine(spec=spec, cfg=cfg, params=params,
                               max_seq=32, batch=2, temperature=0.0)
            eng.prefill({"tokens": prompt})
            # the prompt filled real state: the sLSTM stabilizer moved off
            # its -1e30 init and every leaf is finite
            for k, v in eng.state.items():
                assert bool(jnp.isfinite(v).all()), k
            assert float(eng.state["s_m"].min()) > -1e29
            outs.append(eng.generate(prompt[:, -1:], 8, start_pos=6))
        np.testing.assert_array_equal(outs[0], outs[1])
        assert outs[0].shape == (2, 8)

    def test_prefill_continues_forward(self):
        """Greedy decode from the prefill state equals greedy decode read
        off the teacher-forced forward logits (fused-trained params)."""
        from repro.configs import xlstm_1_3b
        from repro.serving.engine import DecodeEngine
        spec = xlstm_1_3b.SPEC
        cfg = spec.smoke(engine="fused", num_layers=2, slstm_every=2)
        params = self._train_fused(cfg, steps=2)
        tok = jax.random.randint(jax.random.fold_in(KEY, 3), (2, 7),
                                 0, cfg.vocab)
        feats = xlstm.forward(params, tok, cfg)
        ref_next = np.asarray(
            jnp.argmax(xlstm.lm_logits(params, feats)[:, -1], -1))
        eng = DecodeEngine(spec=spec, cfg=cfg, params=params, max_seq=16,
                           batch=2, temperature=0.0)
        eng.prefill({"tokens": tok[:, :-1]})
        first = eng.generate(tok[:, -1:], 1, start_pos=6)
        np.testing.assert_array_equal(first[:, 0], ref_next)


class TestSeq2SeqServingHandoff:
    """Serving regression for the NMT decoder: params trained under
    engine="fused" (the two-pass decoder) hand off to serving/engine.py's
    prefill -> step path — the encoder memory (enc_out / enc_proj /
    score_bias) plus the teacher-forced target replay land (h, c, feed)
    exactly where training-time decoding left them."""

    def _setup_fused(self, steps=3):
        from repro.configs import adapters
        from repro.configs.paper_models import LUONG_NMT
        cfg = LUONG_NMT.smoke(engine="fused")
        batch = jax.tree.map(jnp.asarray, synthetic.nmt_pairs(
            2, cfg.src_vocab, cfg.tgt_vocab, max_len=10, seed=5))
        lfn = adapters.loss_fn("nmt")
        params = adapters.init_params("nmt", KEY, cfg)

        @jax.jit
        def step(p, i):
            l, g = jax.value_and_grad(lambda q: lfn(
                q, batch, cfg, drop_key=jax.random.fold_in(KEY, 100 + i),
                step=i))(p)
            return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), l

        for i in range(steps):
            params, loss = step(params, jnp.int32(i))
        assert bool(jnp.isfinite(loss)), "fused training diverged"
        return LUONG_NMT, cfg, params, batch

    def test_prefill_step_deterministic_finite(self):
        from repro.serving.engine import DecodeEngine
        spec, cfg, params, batch = self._setup_fused()
        tok = batch["tgt_in"]
        T = tok.shape[1]
        outs = []
        for _ in range(2):                 # same prompt twice: deterministic
            eng = DecodeEngine(spec=spec, cfg=cfg, params=params,
                               max_seq=16, batch=2, temperature=0.0)
            eng.prefill({"src": batch["src"], "src_mask": batch["src_mask"],
                         "tgt_in": tok[:, :-1]})
            for k, v in eng.state.items():
                assert bool(jnp.isfinite(v).all()), k
            # prefill parked real attention memory: kept source positions
            # moved their additive score bias off the -1e30 init
            assert float(eng.state["score_bias"].max()) == 0.0
            outs.append(eng.generate(tok[:, -1:], 8, start_pos=T - 1))
        np.testing.assert_array_equal(outs[0], outs[1])
        assert outs[0].shape == (2, 8)

    def test_prefill_continues_forward(self):
        """Greedy first token from the prefill state equals the argmax of
        the teacher-forced forward logits at the last position."""
        from repro.serving.engine import DecodeEngine
        spec, cfg, params, batch = self._setup_fused(steps=2)
        tok = batch["tgt_in"]
        T = tok.shape[1]
        ecfg = dataclasses.replace(cfg, engine="stepwise")
        enc, st = seq2seq.encode(params, batch["src"], ecfg)
        logits = seq2seq.decode_train(params, tok, enc, st, ecfg,
                                      src_mask=batch["src_mask"])
        ref_next = np.asarray(jnp.argmax(logits[:, -1], -1))
        eng = DecodeEngine(spec=spec, cfg=cfg, params=params, max_seq=16,
                           batch=2, temperature=0.0)
        eng.prefill({"src": batch["src"], "src_mask": batch["src_mask"],
                     "tgt_in": tok[:, :-1]})
        first = eng.generate(tok[:, -1:], 1, start_pos=T - 1)
        np.testing.assert_array_equal(first[:, 0], ref_next)


# ---------------------------------------------------------------------------
# Property-based 3-engine equivalence (hypothesis). Random (T, B, H, rate,
# block, case) draws must give allclose forwards AND grads on scheduled /
# stepwise / fused, for the LSTM stack, the sLSTM block, and the seq2seq
# two-pass decoder. The draw pools are small sets so jit compilation stays
# bounded; the checks themselves are exact-shape-generic.
# ---------------------------------------------------------------------------


def _check_lstm_stack_engines(T, B, H, rate, block, case, seed):
    params = lstm_mod.init_lstm_params(jax.random.PRNGKey(seed), 12, H, 2)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, B, 12))
    state = lstm_mod.zero_state(2, B, H)
    bs = block if case in ("case3", "case4") else 1
    plan = DropoutPlan.case(case, rate, block_size=bs, sites=("nr", "rh"))
    ctx = plan.bind(jax.random.PRNGKey(seed + 2), seed % 7)

    def run(engine):
        return lstm_mod.lstm_stack(params, x, state, ctx=ctx, engine=engine)

    y1, s1 = run("stepwise")
    for e in ("scheduled", "fused"):
        y, s = run(e)
        np.testing.assert_allclose(y, y1, rtol=2e-5, atol=2e-5, err_msg=e)
        np.testing.assert_allclose(s.c, s1.c, rtol=2e-5, atol=2e-5)

    def loss(p, engine):
        ys, st = lstm_mod.lstm_stack(p, x, state, ctx=ctx, engine=engine)
        return (ys ** 2).sum() + (st.h ** 2).sum() + (st.c ** 2).sum()

    g1 = jax.grad(lambda p: loss(p, "stepwise"))(params)
    for e in ("scheduled", "fused"):
        g = jax.grad(lambda p: loss(p, e))(params)
        for l in range(len(params)):
            for k in ("W", "U", "b"):
                np.testing.assert_allclose(g[l][k], g1[l][k], rtol=2e-4,
                                           atol=2e-4, err_msg=f"{e} {l}/{k}")


def _check_slstm_block_engines(T, B, heads, dh, rate, block, case, seed):
    cfg = xlstm.XLSTMConfig(num_layers=1, d_model=heads * dh, n_heads=heads,
                            slstm_every=1)
    sl = jax.tree.map(lambda a: a[0], strip(xlstm.init_slstm_block(
        jax.random.PRNGKey(seed), cfg, 1)))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (B, T, cfg.d_model)) * 0.5
    bs = block if case in ("case3", "case4") else 1
    plan = DropoutPlan.case(case, rate, block_size=bs, sites=("rh",))
    ctx = plan.bind(jax.random.PRNGKey(seed + 2), seed % 5)

    def run(p, engine):
        cfg_e = dataclasses.replace(cfg, engine=engine)
        return xlstm.slstm_block_apply(p, x, cfg_e, ctx=ctx, rh_site="rh")

    y1, (h1, st1) = run(sl, "stepwise")
    for e in ("scheduled", "fused"):
        y, (h, st) = run(sl, e)
        np.testing.assert_allclose(y, y1, rtol=2e-5, atol=2e-5, err_msg=e)
        for a, b in zip(st, st1):
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

    def loss(p, engine):
        y, (hf, stf) = run(p, engine)
        return (y ** 2).sum() + (hf ** 2).sum() + (stf[0] ** 2).sum()

    g1 = jax.grad(lambda p: loss(p, "stepwise"))(sl)
    for e in ("scheduled", "fused"):
        g = jax.grad(lambda p: loss(p, e))(sl)
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(g)[0],
                jax.tree_util.tree_flatten_with_path(g1)[0]):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4,
                                       err_msg=f"{e} {path}")


def _check_seq2seq_engines(L, B, H, rate, block, case, seed):
    """Two-pass fused NMT decoder == scheduled == stepwise: loss and every
    param grad (w_feed, split-fan-in decoder, attention, w_comb, embeds,
    fc) agree across the three engines. ``L`` is the synthetic pair
    max_len (>= 8 per synthetic.nmt_pairs); embed != hidden so the hoisted
    layer-0 NR site exercises its own dim."""
    bs = block if case in ("case3", "case4") else 1
    plan = DropoutPlan.case(case, rate, block_size=bs,
                            sites=("nr", "rh", "out"))
    batch = synthetic.nmt_pairs(B, 60, 60, max_len=L, seed=seed % 97)
    cfg = seq2seq.NMTConfig(src_vocab=60, tgt_vocab=60, embed=16, hidden=H,
                            num_layers=2, plan=plan)
    params = seq2seq.init_params(jax.random.PRNGKey(seed), cfg)
    dk = jax.random.PRNGKey(seed + 2)

    def loss(p, engine):
        c = dataclasses.replace(cfg, engine=engine)
        return seq2seq.loss_fn(p, batch, c, drop_key=dk, step=seed % 5)

    l1, g1 = jax.value_and_grad(lambda p: loss(p, "stepwise"))(params)
    for e in ("scheduled", "fused"):
        l, g = jax.value_and_grad(lambda p: loss(p, e))(params)
        np.testing.assert_allclose(l, l1, rtol=2e-5, atol=2e-5, err_msg=e)
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(g)[0],
                jax.tree_util.tree_flatten_with_path(g1)[0]):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4,
                                       err_msg=f"{e} {path}")


def test_engines_equiv_grid():
    """Deterministic mini-grid through the same checks the hypothesis
    properties run (coverage even where hypothesis is not installed)."""
    _check_lstm_stack_engines(T=6, B=3, H=16, rate=0.5, block=4,
                              case="case3", seed=11)
    _check_slstm_block_engines(T=5, B=2, heads=2, dh=16, rate=0.5, block=4,
                               case="case3", seed=12)
    _check_seq2seq_engines(L=9, B=3, H=16, rate=0.5, block=4,
                           case="case3", seed=13)


if hypothesis is not None:
    _ENGINE_DRAW = dict(
        rate=hst.sampled_from((0.25, 0.5, 0.65)),
        block=hst.sampled_from((1, 4, 8)),
        case=hst.sampled_from(CASES),
        seed=hst.integers(0, 2 ** 16),
    )

    class TestEngineProperties:
        @settings(max_examples=6, deadline=None)
        @given(T=hst.sampled_from((2, 5, 9)), B=hst.sampled_from((1, 4)),
               H=hst.sampled_from((16, 24)), **_ENGINE_DRAW)
        def test_lstm_stack(self, T, B, H, rate, block, case, seed):
            _check_lstm_stack_engines(T, B, H, rate, block, case, seed)

        @settings(max_examples=6, deadline=None)
        @given(T=hst.sampled_from((2, 6)), B=hst.sampled_from((1, 3)),
               heads=hst.sampled_from((1, 4)), dh=hst.sampled_from((8, 16)),
               **_ENGINE_DRAW)
        def test_slstm_block(self, T, B, heads, dh, rate, block, case, seed):
            _check_slstm_block_engines(T, B, heads, dh, rate, block, case,
                                       seed)

        @settings(max_examples=6, deadline=None)
        @given(L=hst.sampled_from((8, 11)), B=hst.sampled_from((1, 3)),
               H=hst.sampled_from((16, 24)), **_ENGINE_DRAW)
        def test_seq2seq(self, L, B, H, rate, block, case, seed):
            _check_seq2seq_engines(L, B, H, rate, block, case, seed)
else:                                          # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_engine_properties():
        pass


@pytest.mark.parametrize("hyp", [None])
def test_property_schedule_vs_state(hyp):
    """Property-style sweep: schedule rows == stepwise states across a grid
    of (case, rate, block, T) without requiring hypothesis at runtime."""
    for case in CASES:
        for rate in (0.25, 0.5, 0.65):
            for block in ((1, 8) if case in ("case3", "case4") else (1,)):
                T, B, D = 5, 3, 32
                plan = DropoutPlan.case(case, rate, block_size=block,
                                        sites=("s",))
                ctx = plan.bind(jax.random.PRNGKey(hash((case, block)) %
                                                   (2 ** 31)), 7)
                sched = ctx.schedule("s", T, B, D)
                for t in range(T):
                    st = ctx.state("s", B, D, t=t)
                    row = sched.state(t)
                    a = st.keep_blocks if st.keep_blocks is not None \
                        else st.dense_mask
                    b = row.keep_blocks if row.keep_blocks is not None \
                        else row.dense_mask
                    np.testing.assert_array_equal(
                        np.asarray(a), np.asarray(b),
                        err_msg=f"{case} rate={rate} bs={block} t={t}")
