"""DropoutPlan / DropoutCtx contract tests.

The invariants the unified API guarantees:
  * per-site PRNG streams are independent (different sites => different
    masks) and deterministic (same site + key + step => same mask);
  * FIXED time patterns yield identical masks across the recurrence axis
    through the ctx, PER_STEP re-samples;
  * migrated model forward passes are bit-identical to the deterministic
    path at rate=0, and Case-III applications equal the mask-multiply
    reference;
  * plans round-trip through to_dict/from_dict and the CLI parser.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masks
from repro.core.dropout_plan import DropoutPlan, fit_block
from repro.core.sdrop import DropoutSpec

KEY = jax.random.PRNGKey(7)

CASE3 = DropoutSpec.case("case3", 0.5, block_size=8)
CASE4 = DropoutSpec.case("case4", 0.5, block_size=8)
CASE1 = DropoutSpec.case("case1", 0.5)
CASE2 = DropoutSpec.case("case2", 0.5)


def _kb(ctx, site, t=None, dim=64):
    return np.asarray(ctx.state(site, 4, dim, t=t).keep_blocks)


class TestStreams:
    def test_sites_are_independent(self):
        plan = DropoutPlan({"a": CASE3, "b": CASE3})
        ctx = plan.bind(KEY, 0)
        assert not np.array_equal(_kb(ctx, "a"), _kb(ctx, "b"))

    def test_same_site_reproducible(self):
        plan = DropoutPlan({"a": CASE3})
        k1 = _kb(plan.bind(KEY, 3), "a", t=2)
        k2 = _kb(plan.bind(KEY, 3), "a", t=2)
        assert np.array_equal(k1, k2)

    def test_training_step_resamples(self):
        plan = DropoutPlan({"a": CASE3, "f": CASE4})
        a0 = _kb(plan.bind(KEY, 0), "a")
        a1 = _kb(plan.bind(KEY, 1), "a")
        assert not np.array_equal(a0, a1)
        # even FIXED specs re-sample across *training* steps
        f0 = _kb(plan.bind(KEY, 0), "f", t=0)
        f1 = _kb(plan.bind(KEY, 1), "f", t=0)
        assert not np.array_equal(f0, f1)

    def test_hierarchical_resolution(self):
        plan = DropoutPlan({"nr": CASE3, "enc/layer1/nr": CASE1})
        assert plan.spec("enc/layer0/nr") == CASE3      # basename fallback
        assert plan.spec("enc/layer1/nr") == CASE1      # exact wins
        assert not plan.spec("unknown").active          # default inactive
        wild = DropoutPlan({"*": CASE3})
        assert wild.spec("anything/at/all") == CASE3

    def test_shared_spec_distinct_streams(self):
        """Two sites resolving to the same plan entry get different masks."""
        plan = DropoutPlan({"nr": CASE3})
        ctx = plan.bind(KEY, 0)
        assert not np.array_equal(_kb(ctx, "lstm/layer0/nr"),
                                  _kb(ctx, "lstm/layer1/nr"))


class TestTimePattern:
    def test_fixed_identical_across_t(self):
        ctx = DropoutPlan({"rh": CASE4}).bind(KEY, 0)
        assert np.array_equal(_kb(ctx, "rh", t=0), _kb(ctx, "rh", t=9))

    def test_per_step_resamples_across_t(self):
        ctx = DropoutPlan({"rh": CASE3}).bind(KEY, 0)
        assert not np.array_equal(_kb(ctx, "rh", t=0), _kb(ctx, "rh", t=9))

    def test_random_fixed_mask(self):
        ctx = DropoutPlan({"x": CASE2}).bind(KEY, 0)
        m0 = np.asarray(ctx.state("x", 4, 64, t=0).dense_mask)
        m9 = np.asarray(ctx.state("x", 4, 64, t=9).dense_mask)
        assert np.array_equal(m0, m9)


class TestCtxMechanics:
    def test_deterministic_ctx_is_noop(self):
        plan = DropoutPlan({"a": CASE3})
        for ctx in (plan.bind(None), plan.bind(KEY, deterministic=True)):
            assert ctx.deterministic
            x = jnp.ones((2, 8))
            assert ctx.state("a", 2, 8).inactive
            np.testing.assert_array_equal(ctx.apply("a", x), x)

    def test_apply_equals_mask_multiply(self):
        """Case-III through the ctx == dense mask-multiply reference."""
        ctx = DropoutPlan({"a": CASE3}).bind(KEY, 0)
        x = jax.random.normal(KEY, (3, 5, 64))
        st = ctx.state("a", (3, 5), 64)
        m = masks.keep_blocks_to_mask(st.keep_blocks, 64, 8)
        ref = x * m * st.scale
        np.testing.assert_allclose(np.asarray(ctx.apply("a", x)),
                                   np.asarray(ref), rtol=1e-6)

    def test_random_mask_shaped_to_leading_dims(self):
        ctx = DropoutPlan({"a": CASE1}).bind(KEY, 0)
        st = ctx.state("a", (3, 5), 16)
        assert st.dense_mask.shape == (3, 5, 16)

    def test_block_size_is_clamped_to_divisor(self):
        spec = DropoutSpec.case("case3", 0.5, block_size=128)
        assert fit_block(spec, 64).block_size == 64
        assert fit_block(spec, 96).block_size == 96
        assert fit_block(spec, 256).block_size == 128
        ctx = DropoutPlan({"a": spec}).bind(KEY, 0)
        st = ctx.state("a", 2, 48)          # 128 -> 48
        assert st.keep_blocks is not None


class TestSerialization:
    def test_round_trip(self):
        plan = DropoutPlan({"nr": CASE3, "rh": CASE2,
                            "out": DropoutSpec(rate=0.1, block_size=4,
                                               impl="pallas")})
        assert DropoutPlan.from_dict(plan.to_dict()) == plan

    def test_parse_override(self):
        plan = DropoutPlan.parse("case3:0.5:bs128", sites=("nr", "rh"))
        spec = plan.spec("nr")
        assert spec.case_name == "case3"
        assert spec.rate == 0.5 and spec.block_size == 128
        assert plan.spec("rh") == spec
        assert not DropoutPlan.parse("off").any_active
        with pytest.raises(ValueError):
            DropoutPlan.parse("case9:0.5")
        with pytest.raises(ValueError):
            DropoutPlan.parse("case3")

    def test_adapter_sites_cover_all_kinds(self):
        from repro.configs import adapters
        assert set(adapters.DROPOUT_SITES) == set(adapters._MODULES)
        for kind in adapters.DROPOUT_SITES:
            plan = adapters.dropout_override(kind, "case3:0.5:bs8")
            assert plan.any_active


class TestModelEquivalence:
    def _lm(self, plan):
        from repro.models import lstm_lm
        cfg = lstm_lm.LSTMLMConfig(vocab=64, embed=32, hidden=32,
                                   num_layers=2, plan=plan)
        params = lstm_lm.init_params(KEY, cfg)
        tok = jax.random.randint(KEY, (2, 6), 0, 64)
        return lstm_lm, cfg, params, tok

    def test_rate0_bit_identical_to_deterministic(self):
        """A rate-0 plan with a live key must not perturb the forward pass."""
        zero = DropoutPlan({"embed": DropoutSpec(rate=0.0),
                            "nr": DropoutSpec(rate=0.0)})
        lstm_lm, cfg, params, tok = self._lm(zero)
        with_key, _ = lstm_lm.forward(params, tok, cfg,
                                      ctx=cfg.plan.bind(KEY, 0))
        without, _ = lstm_lm.forward(params, tok, cfg)
        np.testing.assert_array_equal(np.asarray(with_key),
                                      np.asarray(without))

    def test_rate0_transformer_bit_identical(self):
        from repro.models import transformer as T
        from repro.distributed.sharding import strip
        cfg = T.TransformerConfig(num_layers=2, d_model=32, n_heads=4,
                                  n_kv_heads=2, d_ff=64, vocab=50,
                                  plan=DropoutPlan({"nr": DropoutSpec(0.0)}))
        p = strip(T.init_params(KEY, cfg))
        tk = jax.random.randint(KEY, (2, 8), 0, 50)
        a = T.forward(p, tk, cfg, ctx=cfg.plan.bind(KEY, 0))
        b = T.forward(p, tk, cfg)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("case", ["case1", "case2", "case3", "case4"])
    def test_all_cases_train_on_lstm_lm(self, case):
        plan = DropoutPlan.case(case, 0.5, block_size=8,
                                sites=("embed", "nr", "rh", "out"))
        lstm_lm, cfg, params, tok = self._lm(plan)
        batch = {"tokens": tok, "labels": tok}
        loss, grads = jax.value_and_grad(
            lambda p: lstm_lm.loss_fn(p, batch, cfg, drop_key=KEY,
                                      step=0))(params)
        assert jnp.isfinite(loss)
        gn = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
        assert jnp.isfinite(gn) and float(gn) > 0

    def test_slstm_identity_rh_mask_is_noop(self):
        """An all-keep dense RH mask with scale 1 must not perturb sLSTM."""
        from repro.core.sdrop import DropoutState
        from repro.models import xlstm as X
        B, H, dh = 2, 4, 8
        ks = [jax.random.fold_in(KEY, i) for i in range(4)]
        xg = jax.random.normal(ks[0], (B, 4 * H * dh))
        h_prev = jax.random.normal(ks[1], (B, H, dh))
        st = (jnp.zeros((B, H, dh)), jnp.zeros((B, H, dh)),
              jax.random.normal(ks[2], (B, H, dh)))
        R = jax.random.normal(ks[3], (H, dh, 4 * dh)) * dh ** -0.5
        ident = DropoutState(spec=CASE1, dense_mask=jnp.ones((B, 1, dh)),
                             scale=1.0)
        a, sa = X.slstm_step(xg, h_prev, st, R, rh_state=ident)
        b, sb = X.slstm_step(xg, h_prev, st, R, rh_state=None)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
        for x, y in zip(sa, sb):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6)

    def test_case3_changes_forward(self):
        plan = DropoutPlan.case("case3", 0.5, block_size=8,
                                sites=("nr", "rh"))
        lstm_lm, cfg, params, tok = self._lm(plan)
        a, _ = lstm_lm.forward(params, tok, cfg, ctx=cfg.plan.bind(KEY, 0))
        b, _ = lstm_lm.forward(params, tok, cfg)
        assert not np.allclose(np.asarray(a), np.asarray(b))
