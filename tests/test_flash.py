"""flash_attention Pallas kernel vs pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention

KEY = jax.random.PRNGKey(0)


def oracle(q, k, v, causal=True, window=None):
    B, Sq, Hq, d = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kr = jnp.repeat(k, G, axis=2) if G > 1 else k
    vr = jnp.repeat(v, G, axis=2) if G > 1 else v
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * d ** -0.5
    qpos, kpos = jnp.arange(Sq), jnp.arange(Sk)
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)


def mk(B, S, H, Hkv, d, dtype=jnp.float32):
    ks = [jax.random.fold_in(KEY, i) for i in range(3)]
    q = jax.random.normal(ks[0], (B, S, H, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,Hkv,d,bq,bk", [
    (1, 32, 2, 2, 8, 8, 8),
    (2, 64, 4, 2, 16, 16, 16),      # GQA
    (1, 48, 4, 1, 8, 16, 8),        # MQA, uneven blocks
    (2, 32, 2, 2, 8, 32, 32),       # single block
])
@pytest.mark.parametrize("causal", [True, False])
def test_forward(B, S, H, Hkv, d, bq, bk, causal):
    q, k, v = mk(B, S, H, Hkv, d)
    o = flash_attention(q, k, v, causal, None, bq, bk, True)
    np.testing.assert_allclose(o, oracle(q, k, v, causal), atol=2e-5)


@pytest.mark.parametrize("window", [8, 16])
def test_window(window):
    q, k, v = mk(1, 64, 2, 2, 8)
    o = flash_attention(q, k, v, True, window, 16, 16, True)
    np.testing.assert_allclose(o, oracle(q, k, v, True, window), atol=2e-5)


def test_bf16():
    q, k, v = mk(2, 32, 4, 2, 16, jnp.bfloat16)
    o = flash_attention(q, k, v, True, None, 8, 8, True)
    ref = oracle(q, k, v, True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


@pytest.mark.parametrize("B,S,H,Hkv,d", [
    (1, 32, 2, 2, 8),
    (2, 32, 4, 2, 8),               # GQA grads sum over the group
])
@pytest.mark.parametrize("causal", [True, False])
def test_grads(B, S, H, Hkv, d, causal):
    q, k, v = mk(B, S, H, Hkv, d)

    def f_flash(q, k, v):
        return (flash_attention(q, k, v, causal, None, 8, 8, True) ** 2).sum()

    def f_ref(q, k, v):
        return (oracle(q, k, v, causal) ** 2).sum()

    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gr, "qkv"):
        np.testing.assert_allclose(a, b, atol=5e-4,
                                   err_msg=f"d{name} mismatch")


def test_grads_window():
    q, k, v = mk(1, 32, 2, 2, 8)

    def f_flash(q, k, v):
        return (flash_attention(q, k, v, True, 8, 8, 8, True) ** 2).sum()

    def f_ref(q, k, v):
        return (oracle(q, k, v, True, 8) ** 2).sum()

    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(a, b, atol=5e-4)
