"""Table 3: NER sequence labelling — F1 + speedup (BiLSTM-CNN-CRF)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import optim
from repro.data import synthetic
from repro.models import tagger


def _cfg(mode: str, engine="scheduled"):
    rate = 0.5
    kw = dict(vocab=300, char_vocab=40, hidden=200, num_tags=9,
              word_embed=100, char_filters=28,   # 128-dim concat feature
              engine=engine)
    if mode == "baseline":
        return tagger.TaggerConfig(plan=common.plan_random(rate, ("inp",)),
                                   **kw)
    if mode == "nr_st":
        return tagger.TaggerConfig(plan=common.plan_structured(rate, ("inp",)),
                                   **kw)
    return tagger.TaggerConfig(
        plan=common.plan_structured(rate, ("inp", "rh")), **kw)


def f1_score(params, cfg, val):
    pred = np.asarray(tagger.viterbi(params, jax.tree.map(jnp.asarray, val),
                                     cfg))
    gold = val["tags"]
    tp = ((pred == gold) & (gold > 0)).sum()
    fp = ((pred != gold) & (pred > 0)).sum()
    fn = ((pred != gold) & (gold > 0)).sum()
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    return 2 * prec * rec / max(prec + rec, 1e-9)


def run_mode(mode: str, steps: int, batch=32, engine="scheduled"):
    cfg = _cfg(mode, engine=engine)
    key = jax.random.PRNGKey(0)
    params = tagger.init_params(key, cfg)
    opt = optim.chain(optim.clip_by_global_norm(5.0), optim.adamw(2e-3))
    opt_state = opt.init(params)
    val = synthetic.ner_examples(64, cfg.vocab, cfg.char_vocab, cfg.num_tags,
                                 seed=9999)

    @jax.jit
    def step_fn(params, opt_state, b, key):
        l, g = jax.value_and_grad(lambda p: tagger.loss_fn(
            p, b, cfg, drop_key=key))(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return optim.apply_updates(params, upd), opt_state, l

    def batches(i):
        return jax.tree.map(jnp.asarray, synthetic.ner_examples(
            batch, cfg.vocab, cfg.char_vocab, cfg.num_tags, seed=i))

    params, loss, ms = common.train_and_time(step_fn, batches, params,
                                             opt_state, key, steps)
    f1 = f1_score(params, cfg, val)
    return common.RunResult(mode, f1, "F1", ms, loss,
                            dropout_plan=cfg.plan.to_dict(),
                            engine=cfg.engine)


def main(steps: int = 40, quick: bool = False):
    print("=" * 72)
    print("Table 3 — NER (BiLSTM-CNN-CRF, synthetic CoNLL-like tag patterns)")
    print("=" * 72)
    results = [run_mode(m, steps, engine=e)
               for m in ("baseline", "nr_st", "nr_rh_st")
               for e in ("stepwise", "scheduled")]
    print(common.speedup_table(results))
    print(common.engine_ratio_lines(results))
    return {"results": [r.__dict__ for r in results]}


if __name__ == "__main__":
    main()
