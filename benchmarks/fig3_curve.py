"""Fig. 3: validation-perplexity-vs-step curves for the three regimes.

The paper's qualitative claim: NR+RH+ST starts worse but keeps improving
while the baseline flattens (stronger regularization). Prints the curves as
CSV + an ASCII sparkline; the crossover is the reproduced artifact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.table1_ptb import _cfg
from repro import optim
from repro.data import synthetic
from repro.models import lstm_lm


def run_curve(mode: str, steps: int, eval_every: int, batch=20, seq=35):
    cfg = _cfg(mode)
    key = jax.random.PRNGKey(0)
    params = lstm_lm.init_params(key, cfg)
    opt = optim.chain(optim.clip_by_global_norm(5.0), optim.sgd(0.7))
    opt_state = opt.init(params)
    stream = synthetic.lm_stream(cfg.vocab, 400_000, seed=1)
    data = list(synthetic.token_batches(stream[:300_000], batch, seq))
    val = next(synthetic.token_batches(stream[300_000:], batch, seq))
    val = (jnp.asarray(val[0]), jnp.asarray(val[1]))

    @jax.jit
    def step_fn(params, opt_state, tok, lab, key):
        l, g = jax.value_and_grad(lambda p: lstm_lm.loss_fn(
            p, {"tokens": tok, "labels": lab}, cfg, drop_key=key))(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return optim.apply_updates(params, upd), opt_state, l

    curve = []
    for i in range(steps):
        tok, lab = data[i % len(data)]
        params, opt_state, _ = step_fn(params, opt_state, jnp.asarray(tok),
                                       jnp.asarray(lab),
                                       jax.random.fold_in(key, i))
        if (i + 1) % eval_every == 0:
            curve.append(lstm_lm.perplexity(params, *val, cfg))
    return curve


def spark(vals, lo=None, hi=None):
    blocks = "▁▂▃▄▅▆▇█"
    lo = lo if lo is not None else min(vals)
    hi = hi if hi is not None else max(vals)
    rng = max(hi - lo, 1e-9)
    return "".join(blocks[min(7, int((v - lo) / rng * 7.999))] for v in vals)


def main(steps: int = 80, quick: bool = False):
    print("=" * 72)
    print("Fig 3 — validation ppl during training (lower is better)")
    print("=" * 72)
    eval_every = max(steps // 8, 1)
    curves = {m: run_curve(m, steps, eval_every)
              for m in ("baseline", "nr_st", "nr_rh_st")}
    all_v = [v for c in curves.values() for v in c]
    lo, hi = min(all_v), max(all_v)
    print("step," + ",".join(str((i + 1) * eval_every)
                             for i in range(len(next(iter(curves.values()))))))
    for m, c in curves.items():
        print(f"{m}," + ",".join(f"{v:.1f}" for v in c))
    for m, c in curves.items():
        print(f"{m:10s} {spark(c, lo, hi)}  (end {c[-1]:.1f})")
    return {m: c for m, c in curves.items()}


if __name__ == "__main__":
    main()
