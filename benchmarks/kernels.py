"""Kernel microbenchmarks: gather_matmul + lstm_pointwise vs XLA reference.

On this CPU container the Pallas kernels execute in interpret mode (Python)
— wall-clock there is meaningless, so we (a) validate allclose at bench
shapes and (b) time the XLA compaction path (jnp.take + dense dot), which is
what the structured-dropout speedup rides on for the CPU backend, at the
paper's three phase shapes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import masks
from repro.kernels import ops, ref


def _t(f, *a, n=10):
    jax.block_until_ready(f(*a))
    t0 = time.time()
    for _ in range(n):
        out = f(*a)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e3


def main(quick: bool = False):
    print("=" * 72)
    print("Kernels — gather_matmul / lstm_pointwise")
    print("=" * 72)
    key = jax.random.PRNGKey(0)
    out = {}

    # correctness at bench shapes (interpret mode = TPU kernel body semantics)
    B, H, N, bs, rate = (64, 256, 512, 8, 0.5) if quick else \
        (128, 1024, 2048, 128, 0.5)
    a = jax.random.normal(key, (B, H), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (H, N)) / H ** 0.5
    kb = masks.sample_keep_blocks(key, H, rate, bs)
    y = ops.gather_matmul(a, w, kb, block_size=bs, gather="b_rows")
    y_ref = ref.gather_matmul_ref(a, w, kb, block_size=bs, gather="b_rows")
    err = float(jnp.abs(y - y_ref).max())
    print(f"gather_matmul b_rows  ({B}x{H}@{H}x{N}, rate {rate}): "
          f"max|err| = {err:.2e}")
    assert err < 1e-3
    out["gather_matmul_err"] = err

    g = jax.random.normal(key, (B, 4 * H))
    c = jax.random.normal(jax.random.fold_in(key, 2), (B, H))
    h1, c1 = ops.lstm_pointwise(g, c)
    h2, c2 = ref.lstm_pointwise_ref(g, c)
    err2 = float(max(jnp.abs(h1 - h2).max(), jnp.abs(c1 - c2).max()))
    print(f"lstm_pointwise        (B={B}, H={H}): max|err| = {err2:.2e}")
    assert err2 < 1e-5
    out["lstm_pointwise_err"] = err2

    # XLA compaction-path speedups at the paper's phase shapes
    rows = []
    for rate in (0.5, 0.65):
        ids = masks.keep_blocks_to_unit_ids(
            masks.sample_keep_blocks(key, H, rate, bs), bs)
        m = jnp.zeros((H,)).at[ids].set(1.0)
        dense = _t(jax.jit(lambda a, w: (a * m) @ w), a, w)
        comp = _t(jax.jit(lambda a, w: jnp.take(a, ids, 1)
                          @ jnp.take(w, ids, 0)), a, w)
        rows.append((rate, dense, comp, dense / comp))
        print(f"rate {rate}: masked-dense {dense:7.2f} ms  "
              f"compacted {comp:7.2f} ms  speedup {dense/comp:.2f}x "
              f"(ideal {1/(1-rate):.2f}x)")
    out["compaction_speedups"] = rows
    return out


if __name__ == "__main__":
    main()
