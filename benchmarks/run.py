"""Benchmark aggregator: one section per paper table/figure + kernel table.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Emits a summary JSON to results/bench.json as well.

``--snapshot TAG`` switches to perf-trajectory mode: it runs only the
recurrent-engine matrix (benchmarks/engines.py — arch x case x engine
step-times + scheduled/stepwise ratios) and writes ``BENCH_TAG.json`` at
the repo root, so later PRs can regress their step-times against this one:

    PYTHONPATH=src python -m benchmarks.run --snapshot PR2
"""
from __future__ import annotations

import argparse
import json
import os
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer steps (CI-speed)")
    ap.add_argument("--out", default="results/bench.json")
    ap.add_argument("--snapshot", default="",
                    help="perf-trajectory tag (e.g. PR2): run the engine "
                         "matrix only and write BENCH_<tag>.json at the "
                         "repo root")
    args = ap.parse_args(argv)

    if args.snapshot:
        from benchmarks import engines, serving
        path = os.path.join(_REPO_ROOT, f"BENCH_{args.snapshot}.json")
        snap = engines.snapshot(args.snapshot, path, quick=args.quick)
        # serving tokens/sec matrix rides the same snapshot (PR 6): the CI
        # serving gate reads the ``serving_quick`` section the same way the
        # training gate reads ``quick_cells``
        print("\nserving matrix:")
        snap["serving"] = serving.run_matrix(quick=args.quick)
        if not args.quick:
            print("\nserving quick matrix (CI gate baseline):")
            snap["serving_quick"] = serving.run_matrix(quick=True)
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, default=float)
        print(f"\nsnapshot {args.snapshot} (+serving) -> {path}")
        return

    from benchmarks import fig3_curve, table1_ptb, table2_nmt, table3_ner
    from benchmarks import engines
    from benchmarks import kernels as kernel_bench

    t0 = time.time()
    out = {}
    steps1 = 12 if args.quick else 40
    steps23 = 8 if args.quick else 30
    steps_f = 24 if args.quick else 80

    out["table1_ptb"] = table1_ptb.main(steps=steps1, quick=args.quick)
    out["table2_nmt"] = table2_nmt.main(steps=steps23, quick=args.quick)
    out["table3_ner"] = table3_ner.main(steps=steps23, quick=args.quick)
    out["fig3_curve"] = fig3_curve.main(steps=steps_f, quick=args.quick)
    out["engines"] = engines.main(quick=args.quick)
    out["kernels"] = kernel_bench.main(quick=args.quick)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"\nall benchmarks done in {time.time()-t0:.0f}s -> {args.out}")


if __name__ == "__main__":
    main()
