"""Serving benchmark: tokens/sec matrix for the continuous-batching engine.

Two measured quantities, both PAIRED so they port across machines:

  * ``device_vs_python`` — the on-device ``lax.while_loop`` chunk decode
    (one dispatch per chunk) against the pre-PR6 per-token host loop (one
    dispatch + one host sync per token), same params/state/shapes, stepped
    in interleaved rounds; the ratio is the median of per-round paired
    ratios (host-load drift hits both arms of a round equally). This is
    the wall-clock value of moving the decode loop onto the device.
  * ``cont_vs_rect`` — the SAME ragged-arrival trace served through the
    continuous-batching scheduler (evict at chunk boundary, refill the
    slot immediately) and through the rectangular "batch" policy (refill
    only when every slot has drained). Both arms emit the same tokens
    (greedy, per-slot independence), so the time ratio IS the tokens/sec
    ratio. The DISPATCH ratio (rect chunks / cont chunks) is recorded too:
    it is fully deterministic, which is what the CI gate leans on.

    PYTHONPATH=src python -m benchmarks.serving [--quick] [--no-check]

``--quick`` doubles as the CI serving gate: absolute floors first — the
device loop must hold >= 2x over the host loop on at least one recurrent
arch (the acceptance bar; recurrent O(1)-state archs are where the
500k-token serving path lives), and continuous batching must not dispatch
more chunks than the rectangular policy on the ragged trace — then drift
checks against the ``serving_quick`` section of the latest committed
``BENCH_*.json`` (cells absent from the baseline are skipped, so new
archs never fail the gate).
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

import jax
import numpy as np

RECURRENT_KINDS = ("xlstm", "ssm")


# ---------------------------------------------------------------------------
# cells: arch x batch x prompt/gen mix
# ---------------------------------------------------------------------------


def _cells(quick: bool):
    """-> {name: (arch, cfg_overrides, batch, prompt_len, gen)}."""
    tiny_xlstm = dict(num_layers=2, slstm_every=2, d_model=32, vocab=64,
                      n_heads=2)
    if quick:
        return {
            "xlstm_b4": ("xlstm-1.3b", tiny_xlstm, 4, 8, 32),
            "zamba2_b4": ("zamba2-1.2b", dict(num_layers=4), 4, 8, 32),
            "qwen3_b4": ("qwen3-8b", {}, 4, 8, 32),
        }
    return {
        "xlstm_b1": ("xlstm-1.3b", {}, 1, 16, 64),
        "xlstm_b8": ("xlstm-1.3b", {}, 8, 16, 64),
        "zamba2_b8": ("zamba2-1.2b", {}, 8, 16, 64),
        "qwen3_b8": ("qwen3-8b", {}, 8, 16, 64),
    }


def _build(arch: str, overrides: dict, batch: int, max_seq: int,
           chunk: int = 16):
    from repro import configs
    from repro.configs import adapters
    from repro.distributed.sharding import strip
    from repro.serving import DecodeEngine

    spec = configs.get_arch(arch)
    cfg = spec.smoke(**overrides)
    params = strip(adapters.init_params(spec.kind, jax.random.PRNGKey(0),
                                        cfg))
    eng = DecodeEngine(spec=spec, cfg=cfg, params=params, max_seq=max_seq,
                       batch=batch, temperature=0.0, chunk=chunk)
    return spec, cfg, eng


# ---------------------------------------------------------------------------
# device loop vs per-token host loop (paired)
# ---------------------------------------------------------------------------


def time_loops(arch: str, overrides: dict, batch: int, plen: int, gen: int,
               rounds: int):
    """One paired cell: generate ``gen`` tokens with each loop per round."""
    import jax.numpy as jnp

    spec, cfg, eng = _build(arch, overrides, batch, plen + gen)
    vocab = getattr(cfg, "vocab", 128)
    tok0 = jnp.asarray(
        np.random.default_rng(0).integers(3, vocab, (batch, 1)), jnp.int32)

    def run(loop):
        t0 = time.time()
        fn = eng.generate if loop == "device" else eng.generate_python
        out = fn(tok0, gen, start_pos=0)
        assert out.shape == (batch, gen)
        return time.time() - t0

    for loop in ("python", "device"):           # compile both arms
        run(loop)
    times = {"device": [], "python": []}
    for _ in range(rounds):
        for loop in ("python", "device"):
            times[loop].append(run(loop))
    dev = float(np.min(times["device"]))
    py = float(np.min(times["python"]))
    return {
        "device_ms": dev * 1e3,
        "python_ms": py * 1e3,
        "device_toks_per_s": batch * gen / dev,
        "python_toks_per_s": batch * gen / py,
        "device_vs_python": float(np.median(
            [p / d for p, d in zip(times["python"], times["device"])])),
        "kind": spec.kind,
    }


# ---------------------------------------------------------------------------
# ragged-arrival trace: continuous vs rectangular refill (paired)
# ---------------------------------------------------------------------------


def _trace(n: int, vocab: int, seed: int = 0):
    """Ragged arrivals with a long/short budget mix — the workload
    continuous batching exists for: under rectangular refill every short
    request in a group idles until the group's long one drains."""
    rng = np.random.default_rng(seed)
    from repro.serving import Request
    return [Request(rid=i,
                    prompt=rng.integers(3, vocab, int(rng.integers(2, 11))),
                    max_new=24 if i % 4 == 0 else 4)
            for i in range(n)]


def time_trace(arch: str, overrides: dict, slots: int, n_requests: int,
               rounds: int, chunk: int = 8):
    from repro.serving import serve

    spec, cfg, eng = _build(arch, overrides, slots, 64, chunk=chunk)
    reqs = _trace(n_requests, getattr(cfg, "vocab", 128))

    def run(policy):
        t0 = time.time()
        outs = serve(eng, reqs, policy=policy)
        dt = time.time() - t0
        return dt, eng.chunks_run, sum(len(v) for v in outs.values())

    run("batch")                                # compile admit/loop shapes
    run("continuous")
    times = {"continuous": [], "batch": []}
    disp = {}
    total = 0
    for _ in range(rounds):
        for policy in ("batch", "continuous"):
            dt, chunks, total = run(policy)
            times[policy].append(dt)
            disp[policy] = chunks               # deterministic per policy
    cont = float(np.min(times["continuous"]))
    rect = float(np.min(times["batch"]))
    return {
        "requests": n_requests,
        "slots": slots,
        "total_tokens": total,
        "cont_ms": cont * 1e3,
        "rect_ms": rect * 1e3,
        "cont_toks_per_s": total / cont,
        "rect_toks_per_s": total / rect,
        "cont_dispatches": disp["continuous"],
        "rect_dispatches": disp["batch"],
        "dispatch_ratio": disp["batch"] / disp["continuous"],
        "cont_vs_rect": float(np.median(
            [r / c for r, c in zip(times["batch"], times["continuous"])])),
        "kind": spec.kind,
    }


# ---------------------------------------------------------------------------
# matrix + gate
# ---------------------------------------------------------------------------


def run_matrix(quick: bool = False, verbose: bool = True) -> dict:
    rounds = 3 if quick else 5
    loops = {}
    for name, (arch, ov, B, P, G) in _cells(quick).items():
        row = time_loops(arch, ov, B, P, G, rounds)
        loops[name] = row
        if verbose:
            print(f"{name:12s} B={B} gen={G}: device {row['device_ms']:7.1f}"
                  f" ms ({row['device_toks_per_s']:7.0f} tok/s)  python "
                  f"{row['python_ms']:7.1f} ms  "
                  f"speedup {row['device_vs_python']:.2f}x")
        jax.clear_caches()
        gc.collect()
    # trace cell at the default smoke size (8 layers): the decode chunk has
    # to cost more than the admission bookkeeping for the policy comparison
    # to measure scheduling rather than host overhead
    traces = {"xlstm": time_trace(
        "xlstm-1.3b", {}, slots=4, n_requests=12 if quick else 20,
        rounds=rounds)}
    if verbose:
        for name, row in traces.items():
            print(f"trace {name:6s} {row['requests']} reqs/"
                  f"{row['slots']} slots: cont {row['cont_ms']:7.1f} ms "
                  f"({row['cont_dispatches']} dispatches)  rect "
                  f"{row['rect_ms']:7.1f} ms ({row['rect_dispatches']})  "
                  f"ratio {row['cont_vs_rect']:.2f}x "
                  f"(dispatch {row['dispatch_ratio']:.2f}x)")
    jax.clear_caches()
    gc.collect()
    return {"loops": loops, "trace": traces}


def check_floors(matrix: dict, min_recurrent_speedup: float = 2.0) -> list:
    """Machine-portable absolute floors (the PR acceptance bar)."""
    failures = []
    rec = {n: r["device_vs_python"] for n, r in matrix["loops"].items()
           if r.get("kind") in RECURRENT_KINDS}
    if rec and max(rec.values()) < min_recurrent_speedup:
        failures.append(
            f"device loop < {min_recurrent_speedup}x over the per-token "
            f"host loop on every recurrent arch: {rec}")
    for name, row in matrix["trace"].items():
        if row["dispatch_ratio"] <= 1.0:
            failures.append(
                f"trace {name}: continuous batching did not save device "
                f"dispatches (cont {row['cont_dispatches']} vs rect "
                f"{row['rect_dispatches']})")
    return failures


def check_regression(matrix: dict, baseline_path: str,
                     tolerance: float = 1.5, quick: bool = True) -> list:
    """Drift of the paired ratios vs the latest committed snapshot.

    Quick runs compare against the snapshot's ``serving_quick`` section
    (same geometries). A baseline predating the serving sections skips
    with a note — the absolute floors above still gate.
    """
    with open(baseline_path) as f:
        base = json.load(f)
    sect = base.get("serving_quick" if quick else "serving")
    if not sect:
        print("  (baseline has no serving section — drift check skipped, "
              "absolute floors still apply)")
        return []
    failures = []
    for name, row in matrix["loops"].items():
        b = sect.get("loops", {}).get(name)
        if not b or "device_vs_python" not in b:
            continue
        drift = b["device_vs_python"] / row["device_vs_python"]
        status = "FAIL" if drift > tolerance else "ok"
        print(f"  gate {name:12s} [device_vs_python]: baseline "
              f"{b['device_vs_python']:.2f}x now "
              f"{row['device_vs_python']:.2f}x  drift {drift:.2f} "
              f"[{status}]")
        if drift > tolerance:
            failures.append(
                f"{name}: device-loop speedup fell "
                f"{b['device_vs_python']:.2f}x -> "
                f"{row['device_vs_python']:.2f}x (> {tolerance}x drift)")
    for name, row in matrix["trace"].items():
        b = sect.get("trace", {}).get(name)
        if not b or "dispatch_ratio" not in b:
            continue
        # dispatch counts are deterministic: a scheduler change that makes
        # continuous batching save fewer chunks shows up exactly here
        drift = b["dispatch_ratio"] / row["dispatch_ratio"]
        status = "FAIL" if drift > tolerance else "ok"
        print(f"  gate trace {name:6s} [dispatch_ratio]: baseline "
              f"{b['dispatch_ratio']:.2f}x now {row['dispatch_ratio']:.2f}x"
              f"  drift {drift:.2f} [{status}]")
        if drift > tolerance:
            failures.append(
                f"trace {name}: dispatch savings fell "
                f"{b['dispatch_ratio']:.2f}x -> {row['dispatch_ratio']:.2f}x")
    return failures


def main(quick: bool = False, check: bool = True, out: str = "") -> dict:
    matrix = run_matrix(quick=quick)
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(matrix, f, indent=1, default=float)
        print(f"serving matrix -> {out}")
    if quick and check:
        failures = check_floors(matrix)
        from benchmarks import engines
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        baseline = engines.latest_baseline(root)
        if baseline:
            print(f"\nserving gate vs {os.path.basename(baseline)}:")
            failures += check_regression(matrix, baseline, quick=True)
        else:
            print("serving gate: no BENCH_*.json baseline, floors only")
        if failures:
            for msg in failures:
                print(f"SERVING REGRESSION: {msg}", file=sys.stderr)
            sys.exit(1)
        print("serving gate: pass")
    return matrix


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the --quick serving gate")
    ap.add_argument("--out", default="",
                    help="also write the matrix JSON here (CI artifact)")
    args = ap.parse_args()
    main(quick=args.quick, check=not args.no_check, out=args.out)
