"""Shared benchmark machinery.

Each table benchmark trains the paper's model on matched synthetic data
under three dropout regimes —
    baseline   : NR+Random  (Case-I, Zaremba'14-style; no compute reclaim)
    nr_st      : NR+ST      (Case-III, non-recurrent only)
    nr_rh_st   : NR+RH+ST   (Case-III, both directions — the paper's best)
— and reports (a) the task metric at equal step budget, (b) measured
wall-clock per training step on this host (CPU backend), and (c) the FLOP
reduction implied by the compacted matmuls (exact, from the config).

The paper's GPU numbers (1.23x-1.64x) are wall-clock on a TITAN V; ours are
CPU wall-clock + roofline terms for the TPU target — the *relative*
structure (NR+RH+ST > NR+ST > baseline; metric parity) is the reproduced
claim.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.dropout_plan import DropoutPlan


def plan_random(rate, sites):
    """Case-I (random x per-step) at every named site — the baseline."""
    return DropoutPlan.case("case1", rate, sites=sites)


def plan_structured(rate, sites, block=8):
    """Case-III (structured x per-step) at every named site — the paper."""
    return DropoutPlan.case("case3", rate, block_size=block, sites=sites)


@dataclasses.dataclass
class RunResult:
    name: str
    metric: float
    metric_name: str
    ms_per_step: float
    final_loss: float
    # exact dropout pattern that ran, for the benchmark JSON record
    dropout_plan: Optional[dict] = None
    # recurrent execution engine the run used ("scheduled" | "stepwise")
    engine: str = ""

    def row(self):
        label = f"{self.name}/{self.engine}" if self.engine else self.name
        return (f"{label:22s} {self.metric_name}={self.metric:8.3f}  "
                f"{self.ms_per_step:7.1f} ms/step  loss={self.final_loss:.3f}")


def train_and_time(step_fn: Callable, batches, params, opt_state, key,
                   steps: int, warmup: int = 3):
    """Runs `steps` steps; returns (params, loss, ms/step after warmup)."""
    t0, n = None, 0
    loss = jnp.zeros(())
    for i in range(steps):
        batch = batches(i)
        params, opt_state, loss = step_fn(params, opt_state, batch,
                                          jax.random.fold_in(key, i))
        if i == warmup - 1:
            jax.block_until_ready(loss)
            t0 = time.time()
        elif i >= warmup:
            n += 1
    jax.block_until_ready(loss)
    dt = (time.time() - t0) / max(n, 1) if t0 else float("nan")
    return params, float(loss), dt * 1e3


def speedup_table(results: list, baseline: str = "baseline"):
    """Rows + speedup vs the baseline run (same engine when engines vary)."""
    def base_for(r):
        cands = [b for b in results if b.name == baseline]
        same = [b for b in cands if b.engine == r.engine]
        return (same or cands)[0]

    lines = []
    for r in results:
        base = base_for(r)
        lines.append(f"{r.row()}   speedup vs {baseline}: "
                     f"{base.ms_per_step / r.ms_per_step:5.2f}x")
    return "\n".join(lines)


def engine_ratio_lines(results: list):
    """scheduled/stepwise wall-clock ratio per dropout mode."""
    lines = []
    for name in {r.name for r in results}:
        by_eng = {r.engine: r for r in results if r.name == name}
        if "stepwise" in by_eng and "scheduled" in by_eng:
            ratio = by_eng["stepwise"].ms_per_step / \
                by_eng["scheduled"].ms_per_step
            lines.append(f"  {name:12s} scheduled-engine speedup: {ratio:.2f}x")
    return "\n".join(sorted(lines))
