"""Table 2: machine translation — token-accuracy proxy for BLEU + speedup.

Scaled Luong NMT on synthetic copy+permute pairs. BLEU needs a real
detokenized corpus; on synthetic pairs we report greedy next-token accuracy
on held-out pairs (monotone with BLEU for this task family).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import optim
from repro.data import synthetic
from repro.models import seq2seq


def _cfg(mode: str, hidden=512, engine="scheduled"):
    rate = 0.3
    if mode == "baseline":
        plan = common.plan_random(rate, sites=("nr",))
    elif mode == "nr_st":
        plan = common.plan_structured(rate, sites=("nr", "out"))
    else:  # nr_rh_st
        plan = common.plan_structured(rate, sites=("nr", "rh", "out"))
    return seq2seq.NMTConfig(src_vocab=500, tgt_vocab=500, embed=hidden,
                             hidden=hidden, plan=plan, engine=engine)


def token_accuracy(params, cfg, val):
    enc, st = seq2seq.encode(params, jnp.asarray(val["src"]), cfg)
    logits = seq2seq.decode_train(params, jnp.asarray(val["tgt_in"]), enc,
                                  st, cfg,
                                  src_mask=jnp.asarray(val["src_mask"]))
    pred = jnp.argmax(logits, -1)
    mask = jnp.asarray(val["tgt_mask"])
    return float((jnp.asarray(val["tgt_out"]) == pred)[mask].mean())


def run_mode(mode: str, steps: int, batch=32, hidden=512,
             engine="scheduled"):
    cfg = _cfg(mode, hidden=hidden, engine=engine)
    key = jax.random.PRNGKey(0)
    params = seq2seq.init_params(key, cfg)
    opt = optim.chain(optim.clip_by_global_norm(5.0), optim.adamw(2e-3))
    opt_state = opt.init(params)
    val = synthetic.nmt_pairs(64, cfg.src_vocab, cfg.tgt_vocab, seed=9999)

    @jax.jit
    def step_fn(params, opt_state, b, key):
        l, g = jax.value_and_grad(lambda p: seq2seq.loss_fn(
            p, b, cfg, drop_key=key))(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return optim.apply_updates(params, upd), opt_state, l

    def batches(i):
        return jax.tree.map(jnp.asarray, synthetic.nmt_pairs(
            batch, cfg.src_vocab, cfg.tgt_vocab, seed=i))

    params, loss, ms = common.train_and_time(step_fn, batches, params,
                                             opt_state, key, steps)
    acc = token_accuracy(params, cfg, val)
    return common.RunResult(mode, acc, "tok_acc", ms, loss,
                            dropout_plan=cfg.plan.to_dict(),
                            engine=cfg.engine)


def main(steps: int = 20, quick: bool = False):
    print("=" * 72)
    print("Table 2 — NMT (Luong seq2seq geometry, synthetic De-En-like pairs)")
    print("=" * 72)
    hidden = 128 if quick else 512     # full mode = the paper's true width
    results = [run_mode(m, steps, hidden=hidden, engine=e)
               for m in ("baseline", "nr_st", "nr_rh_st")
               for e in ("stepwise", "scheduled")]
    print(common.speedup_table(results))
    print(common.engine_ratio_lines(results))
    return {"results": [r.__dict__ for r in results]}


if __name__ == "__main__":
    main()
