"""Engine matrix benchmark: arch x dropout case x engine step-times.

Times one full training step (fwd + bwd + optimizer, jitted, CPU backend)
for every recurrent arch under every dropout case, on both recurrent
engines, and reports the scheduled/stepwise ratio — the wall-clock value of
hoisting mask sampling and the NR gate matmuls out of the ``lax.scan``.

    PYTHONPATH=src python -m benchmarks.engines [--quick]

``snapshot()`` is the perf-trajectory entry point: ``benchmarks.run
--snapshot PR2`` calls it and writes ``BENCH_PR2.json`` at the repo root so
future PRs can regress against this PR's step-times. The snapshot includes
the acceptance cell ``lstm_lm_ptb_large`` — the Zaremba-large recurrent
geometry (2x1500, rate .65, batch 20, unroll 35; bench-reduced vocab so the
softmax does not mask the recurrent engine under test).
"""
from __future__ import annotations

import argparse
import gc
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.dropout_plan import DropoutPlan
from repro.core.lstm import ENGINES as _ALL_ENGINES
from repro.data import synthetic
from repro.models import lstm_lm, seq2seq, tagger, xlstm

# measurement order: stepwise first, then scheduled, within each round
ENGINES = tuple(sorted(_ALL_ENGINES, reverse=True))
CASES = ("case1", "case2", "case3", "case4")


# ---------------------------------------------------------------------------
# cell definitions: (kind, cfg_fn(case, engine), batch, seq)
# ---------------------------------------------------------------------------


def _plan(kind: str, case: str, rate: float, block: int) -> DropoutPlan:
    sites = {
        "lstm_lm": ("embed", "nr", "rh", "out"),
        "nmt": ("nr", "rh", "out"),
        "tagger": ("inp", "rh"),
        "xlstm": ("nr", "rh"),
    }[kind]
    bs = block if case in ("case3", "case4") else 1
    return DropoutPlan.case(case, rate, block_size=bs, sites=sites)


def _cells(quick: bool):
    """-> {name: (kind, cfg_fn(case, engine), batch, seq, steps)}."""
    s = 4 if quick else 12
    h_lm = 128 if quick else 256
    d_x = 128 if quick else 256
    bs_x = 8 if quick else 16
    sq_x = 32 if quick else 48
    cells = {
        "lstm_lm": ("lstm_lm", lambda case, eng: lstm_lm.LSTMLMConfig(
            vocab=1000, embed=h_lm, hidden=h_lm, num_layers=2,
            plan=_plan("lstm_lm", case, 0.5, 8), engine=eng), 16, 32, s),
        "nmt": ("nmt", lambda case, eng: seq2seq.NMTConfig(
            src_vocab=500, tgt_vocab=500, embed=h_lm, hidden=h_lm,
            num_layers=2, plan=_plan("nmt", case, 0.3, 8), engine=eng),
            16, 24, s),
        "tagger": ("tagger", lambda case, eng: tagger.TaggerConfig(
            vocab=300, char_vocab=40, hidden=128, num_tags=9,
            word_embed=100, char_filters=28,
            plan=_plan("tagger", case, 0.5, 8), engine=eng), 16, 24, s),
        # all-sLSTM so the time-scan (the part the engine changes) dominates;
        # sized so the step is well above the host-noise floor (~40 ms cells
        # measured +/-20% run-to-run; >=150 ms cells are stable)
        "xlstm": ("xlstm", lambda case, eng: xlstm.XLSTMConfig(
            num_layers=4, d_model=d_x, n_heads=4, vocab=256, chunk=16,
            slstm_every=1, plan=_plan("xlstm", case, 0.5, 8), engine=eng),
            bs_x, sq_x, s),
    }
    return cells


def _acceptance_cell(quick: bool):
    """The PTB-large case3 cell (paper Table 1 geometry, reduced vocab)."""
    H = 512 if quick else 1500
    steps = 3 if quick else 5
    return ("lstm_lm", lambda case, eng: lstm_lm.LSTMLMConfig(
        vocab=2000, embed=H, hidden=H, num_layers=2,
        plan=_plan("lstm_lm", case, 0.65, 4), engine=eng), 20, 35, steps)


# ---------------------------------------------------------------------------
# one timed cell
# ---------------------------------------------------------------------------


def _batch_fn(kind: str, cfg, batch: int, seq: int):
    if kind in ("lstm_lm", "xlstm"):
        vocab = cfg.vocab
        stream = synthetic.lm_stream(vocab, batch * (seq + 1) * 8, seed=0)

        def fn(i):
            n = batch * (seq + 1)
            off = (i * n) % (len(stream) - n - 1)
            chunk = stream[off:off + n].reshape(batch, seq + 1)
            return {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}
        return fn
    if kind == "nmt":
        return lambda i: synthetic.nmt_pairs(batch, cfg.src_vocab,
                                             cfg.tgt_vocab, max_len=seq,
                                             seed=i)
    if kind == "tagger":
        return lambda i: synthetic.ner_examples(batch, cfg.vocab,
                                                cfg.char_vocab, cfg.num_tags,
                                                seq=seq, seed=i)
    raise ValueError(kind)


class _Runner:
    """One jitted training cell (params + opt state + batches), steppable."""

    def __init__(self, kind, cfg, batch, seq, n_batches):
        from repro.configs import adapters
        from repro.distributed.sharding import strip

        lfn = adapters.loss_fn(kind)
        self.key = jax.random.PRNGKey(0)
        self.params = strip(adapters.init_params(kind, self.key, cfg))
        self.opt = optim.chain(optim.clip_by_global_norm(1.0),
                               optim.adamw(1e-3))
        self.opt_state = self.opt.init(self.params)
        bf = _batch_fn(kind, cfg, batch, seq)
        self.batches = [jax.tree.map(jnp.asarray, bf(i))
                        for i in range(n_batches)]

        @jax.jit
        def step_fn(params, opt_state, b, key, i):
            l, g = jax.value_and_grad(
                lambda p: lfn(p, b, cfg, drop_key=key, step=i))(params)
            upd, opt_state = self.opt.update(g, opt_state, params)
            return optim.apply_updates(params, upd), opt_state, l

        self._step = step_fn

    def step(self, i):
        b = self.batches[i % len(self.batches)]
        self.params, self.opt_state, loss = self._step(
            self.params, self.opt_state, b,
            jax.random.fold_in(self.key, i), jnp.int32(i))
        jax.block_until_ready(loss)


def time_engines(kind, cfg_fn, case, batch, seq, steps, warmup=2):
    """Paired step-times + ratio for one (arch, case) cell.

    Both engines' cells are built up front, then stepped in interleaved
    rounds (A/B per round) so host-load drift hits both equally. Reported
    ms are best-observed (noise only ever adds); the ratio is the MEDIAN
    of per-round paired ratios — the drift-cancelling estimator (a single
    slow round perturbs each engine once, in the same round).
    """
    runners = {eng: _Runner(kind, cfg_fn(case, eng), batch, seq,
                            warmup + steps) for eng in ENGINES}
    for eng in ENGINES:
        for i in range(warmup):
            runners[eng].step(i)
    times = {eng: [] for eng in ENGINES}
    for i in range(warmup, warmup + steps):
        for eng in ENGINES:
            t0 = time.time()
            runners[eng].step(i)
            times[eng].append(time.time() - t0)
    out = {eng: float(np.min(ts) * 1e3) for eng, ts in times.items()}
    out["ratio"] = float(np.median([a / b for a, b in
                                    zip(times["stepwise"],
                                        times["scheduled"])]))
    return out


# ---------------------------------------------------------------------------
# matrix + snapshot
# ---------------------------------------------------------------------------


def run_matrix(quick: bool = False, cases=CASES, verbose: bool = True):
    out = {}
    cells = dict(_cells(quick))
    cells["lstm_lm_ptb_large"] = _acceptance_cell(quick)
    for name, (kind, cfg_fn, B, S, steps) in cells.items():
        run_cases = ("case3",) if name == "lstm_lm_ptb_large" else cases
        out[name] = {}
        for case in run_cases:
            row = time_engines(kind, cfg_fn, case, B, S, steps)
            out[name][case] = row
            if verbose:
                print(f"{name:20s} {case}: stepwise {row['stepwise']:8.1f} ms"
                      f"  scheduled {row['scheduled']:8.1f} ms"
                      f"  ratio {row['ratio']:.2f}x")
            # drop this cell's executables/buffers before the next one —
            # long-process allocator state was measured skewing small cells
            jax.clear_caches()
            gc.collect()
    return out


def arch_ratios(cells: dict) -> dict:
    """Per-arch scheduled-engine speedup: geometric mean over that arch's
    case cells (individual ~40-400 ms cells carry a few % host noise; the
    per-arch aggregate is the stable quantity)."""
    out = {}
    for name, by_case in cells.items():
        rs = [row["ratio"] for row in by_case.values()]
        out[name] = float(np.exp(np.mean(np.log(rs))))
    return out


def snapshot(tag: str, out_path: str, quick: bool = False) -> dict:
    cells = run_matrix(quick=quick)
    snap = {
        "tag": tag,
        "backend": jax.default_backend(),
        "impl": "xla",
        "quick": bool(quick),
        "cells": cells,
        # scheduled/stepwise per arch (geomean over cases): the headline
        # "no slower on any recurrent arch" number
        "arch_ratios": arch_ratios(cells),
    }
    with open(out_path, "w") as f:
        json.dump(snap, f, indent=1, default=float)
    print(f"\nsnapshot {tag} -> {out_path}")
    for name, r in snap["arch_ratios"].items():
        print(f"  {name:20s} scheduled-engine speedup {r:.2f}x")
    return snap


def main(quick: bool = False):
    return run_matrix(quick=quick)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
