"""Engine matrix benchmark: arch x dropout case x engine step-times.

Times one full training step (fwd + bwd + optimizer, jitted, CPU backend)
for every recurrent arch under every dropout case, on all three recurrent
engines, and reports the paired engine ratios — ``ratio`` =
stepwise/scheduled (the wall-clock value of hoisting mask sampling and the
NR gate matmuls out of the ``lax.scan``) and ``fused_vs_scheduled`` =
scheduled/fused (the additional value of running Phase B as one fused pass
per layer — kernels/cell_scan.py machinery, instantiated as lstm_scan for
the LSTM archs and slstm_scan for xlstm's sLSTM blocks).

    PYTHONPATH=src python -m benchmarks.engines [--quick] [--out PATH]

``--quick`` doubles as the CI perf-regression gate: after the (reduced-size)
matrix it loads the latest committed ``BENCH_*.json`` at the repo root and
FAILS (exit 1) on a regression of either paired ratio (scheduled AND
fused — the xlstm fused cells are gated since PR 5, the two-pass fused
NMT decoder cells incl. the IWSLT acceptance geometry since PR 7) or of
the PR 8 ragged cell (``run_ragged``: token-packed vs rectangular
effective tokens/sec on a skewed-length corpus — absolute ``RAGGED_FLOOR``
plus drift vs the snapshot's ``ragged_quick`` row). Ratios
— not
absolute ms — are what gates portably: both engines of a pair run
interleaved on the same host, so the paired ratio cancels machine speed and
host-load drift, while CI runners and dev machines disagree wildly on raw
step times. Two further design points, both measured:

  * quick-mode cells are compared against the snapshot's ``quick_cells``
    (snapshots since PR 3 record the quick matrix alongside the full one) —
    quick geometries have legitimately different ratios than full ones, so
    cross-size comparison false-positives (older snapshots without
    quick_cells fall back to the full cells, warned);
  * the ~40-400 ms quick cells carry enough host noise that a single
    paired-median ratio swings ~1.25x run-to-run, so the per arch x case
    check uses a 1.5x tolerance and the tight 1.25x bound is applied to
    the per-arch GEOMEAN over its cases (the stable quantity) — a cell
    collapse trips the first, a broad slowdown the second.

``--no-check`` skips the gate.

``snapshot()`` is the perf-trajectory entry point: ``benchmarks.run
--snapshot PR3`` calls it and writes ``BENCH_PR3.json`` at the repo root so
future PRs can regress against this PR's step-times. The snapshot includes
two acceptance cells: ``lstm_lm_ptb_large`` — the Zaremba-large recurrent
geometry (2x1500, rate .65, batch 20, unroll 35; bench-reduced vocab so the
softmax does not mask the recurrent engine under test) — and ``nmt_iwslt``
— the Luong IWSLT decoder geometry (2x512, input feeding, rate .3), whose
``fused_vs_scheduled`` ratio prices the two-pass fused decoder.
"""
from __future__ import annotations

import argparse
import gc
import glob
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.dropout_plan import DropoutPlan
from repro.core.lstm import ENGINES as _ALL_ENGINES
from repro.data import synthetic
from repro.models import lstm_lm, seq2seq, tagger, xlstm

# measurement order within each round: reference first, then the two
# restructured engines in the order they were introduced
ENGINES = ("stepwise", "scheduled", "fused")
assert set(ENGINES) == set(_ALL_ENGINES), (ENGINES, _ALL_ENGINES)
# (numerator, denominator, row key) for the paired per-round ratios
RATIO_PAIRS = (("stepwise", "scheduled", "ratio"),
               ("scheduled", "fused", "fused_vs_scheduled"))
CASES = ("case1", "case2", "case3", "case4")


# ---------------------------------------------------------------------------
# cell definitions: (kind, cfg_fn(case, engine), batch, seq)
# ---------------------------------------------------------------------------


def _plan(kind: str, case: str, rate: float, block: int) -> DropoutPlan:
    sites = {
        "lstm_lm": ("embed", "nr", "rh", "out"),
        "nmt": ("nr", "rh", "out"),
        "tagger": ("inp", "rh"),
        "xlstm": ("nr", "rh"),
    }[kind]
    bs = block if case in ("case3", "case4") else 1
    return DropoutPlan.case(case, rate, block_size=bs, sites=sites)


def _cells(quick: bool):
    """-> {name: (kind, cfg_fn(case, engine), batch, seq, steps)}."""
    s = 4 if quick else 12
    h_lm = 128 if quick else 256
    d_x = 128 if quick else 256
    bs_x = 8 if quick else 16
    sq_x = 32 if quick else 48
    cells = {
        "lstm_lm": ("lstm_lm", lambda case, eng: lstm_lm.LSTMLMConfig(
            vocab=1000, embed=h_lm, hidden=h_lm, num_layers=2,
            plan=_plan("lstm_lm", case, 0.5, 8), engine=eng), 16, 32, s),
        "nmt": ("nmt", lambda case, eng: seq2seq.NMTConfig(
            src_vocab=500, tgt_vocab=500, embed=h_lm, hidden=h_lm,
            num_layers=2, plan=_plan("nmt", case, 0.3, 8), engine=eng),
            16, 24, s),
        "tagger": ("tagger", lambda case, eng: tagger.TaggerConfig(
            vocab=300, char_vocab=40, hidden=128, num_tags=9,
            word_embed=100, char_filters=28,
            plan=_plan("tagger", case, 0.5, 8), engine=eng), 16, 24, s),
        # all-sLSTM so the time-scan (the part the engine changes) dominates;
        # sized so the step is well above the host-noise floor (~40 ms cells
        # measured +/-20% run-to-run; >=150 ms cells are stable)
        "xlstm": ("xlstm", lambda case, eng: xlstm.XLSTMConfig(
            num_layers=4, d_model=d_x, n_heads=4, vocab=256, chunk=16,
            slstm_every=1, plan=_plan("xlstm", case, 0.5, 8), engine=eng),
            bs_x, sq_x, s),
    }
    return cells


def _acceptance_cell(quick: bool):
    """The PTB-large case3 cell (paper Table 1 geometry, reduced vocab)."""
    H = 512 if quick else 1500
    steps = 3 if quick else 5
    return ("lstm_lm", lambda case, eng: lstm_lm.LSTMLMConfig(
        vocab=2000, embed=H, hidden=H, num_layers=2,
        plan=_plan("lstm_lm", case, 0.65, 4), engine=eng), 20, 35, steps)


def _iwslt_cell(quick: bool):
    """The Luong IWSLT En-Vi decoder geometry (2x512, input feeding,
    rate .3), bench-reduced vocab so the softmax does not mask the decoder
    recurrence under test. This is the acceptance cell for the two-pass
    fused decoder: engine="fused" hoists the layer-0 embedding matmuls out
    of the attention scan at (1-p) FLOPs (models/seq2seq.py)."""
    H = 256 if quick else 512
    steps = 3 if quick else 5
    seq = 24 if quick else 40
    return ("nmt", lambda case, eng: seq2seq.NMTConfig(
        src_vocab=1000, tgt_vocab=1000, embed=H, hidden=H, num_layers=2,
        plan=_plan("nmt", case, 0.3, 8), engine=eng), 16, seq, steps)


# acceptance-geometry cells run a single representative case (case3)
ACCEPTANCE_CELLS = ("lstm_lm_ptb_large", "nmt_iwslt")


# ---------------------------------------------------------------------------
# one timed cell
# ---------------------------------------------------------------------------


def _batch_fn(kind: str, cfg, batch: int, seq: int):
    if kind in ("lstm_lm", "xlstm"):
        vocab = cfg.vocab
        stream = synthetic.lm_stream(vocab, batch * (seq + 1) * 8, seed=0)

        def fn(i):
            n = batch * (seq + 1)
            off = (i * n) % (len(stream) - n - 1)
            chunk = stream[off:off + n].reshape(batch, seq + 1)
            return {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}
        return fn
    if kind == "nmt":
        return lambda i: synthetic.nmt_pairs(batch, cfg.src_vocab,
                                             cfg.tgt_vocab, max_len=seq,
                                             seed=i)
    if kind == "tagger":
        return lambda i: synthetic.ner_examples(batch, cfg.vocab,
                                                cfg.char_vocab, cfg.num_tags,
                                                seq=seq, seed=i)
    raise ValueError(kind)


class _Runner:
    """One jitted training cell (params + opt state + batches), steppable."""

    def __init__(self, kind, cfg, batch, seq, n_batches):
        from repro.configs import adapters
        from repro.distributed.sharding import strip

        lfn = adapters.loss_fn(kind)
        self.key = jax.random.PRNGKey(0)
        self.params = strip(adapters.init_params(kind, self.key, cfg))
        self.opt = optim.chain(optim.clip_by_global_norm(1.0),
                               optim.adamw(1e-3))
        self.opt_state = self.opt.init(self.params)
        bf = _batch_fn(kind, cfg, batch, seq)
        self.batches = [jax.tree.map(jnp.asarray, bf(i))
                        for i in range(n_batches)]

        @jax.jit
        def step_fn(params, opt_state, b, key, i):
            l, g = jax.value_and_grad(
                lambda p: lfn(p, b, cfg, drop_key=key, step=i))(params)
            upd, opt_state = self.opt.update(g, opt_state, params)
            return optim.apply_updates(params, upd), opt_state, l

        self._step = step_fn

    def step(self, i):
        b = self.batches[i % len(self.batches)]
        self.params, self.opt_state, loss = self._step(
            self.params, self.opt_state, b,
            jax.random.fold_in(self.key, i), jnp.int32(i))
        jax.block_until_ready(loss)


def time_engines(kind, cfg_fn, case, batch, seq, steps, warmup=2):
    """Paired step-times + ratio for one (arch, case) cell.

    Both engines' cells are built up front, then stepped in interleaved
    rounds (A/B per round) so host-load drift hits both equally. Reported
    ms are best-observed (noise only ever adds); the ratio is the MEDIAN
    of per-round paired ratios — the drift-cancelling estimator (a single
    slow round perturbs each engine once, in the same round).
    """
    runners = {eng: _Runner(kind, cfg_fn(case, eng), batch, seq,
                            warmup + steps) for eng in ENGINES}
    for eng in ENGINES:
        for i in range(warmup):
            runners[eng].step(i)
    times = {eng: [] for eng in ENGINES}
    for i in range(warmup, warmup + steps):
        for eng in ENGINES:
            t0 = time.time()
            runners[eng].step(i)
            times[eng].append(time.time() - t0)
    out = {eng: float(np.min(ts) * 1e3) for eng, ts in times.items()}
    for num, den, key in RATIO_PAIRS:
        out[key] = float(np.median([a / b for a, b in
                                    zip(times[num], times[den])]))
    return out


# ---------------------------------------------------------------------------
# ragged cell: token-packed vs rectangular padding (PR 8)
# ---------------------------------------------------------------------------


def _ragged_cfg(quick: bool):
    H = 128 if quick else 256
    return lstm_lm.LSTMLMConfig(
        vocab=1000, embed=H, hidden=H, num_layers=2,
        plan=_plan("lstm_lm", "case3", 0.5, 8), engine="scheduled")


class _RaggedRunner:
    """One jitted LM training cell stepped over externally supplied batches
    (the ragged bench feeds several static shapes — one trace per bucket
    cap; all traces are compiled during the warmup epoch)."""

    def __init__(self, cfg):
        from repro.configs import adapters
        from repro.distributed.sharding import strip

        lfn = adapters.loss_fn("lstm_lm")
        self.key = jax.random.PRNGKey(0)
        self.params = strip(adapters.init_params("lstm_lm", self.key, cfg))
        self.opt = optim.chain(optim.clip_by_global_norm(1.0),
                               optim.adamw(1e-3))
        self.opt_state = self.opt.init(self.params)

        @jax.jit
        def step_fn(params, opt_state, b, key, i):
            l, g = jax.value_and_grad(
                lambda p: lfn(p, b, cfg, drop_key=key, step=i))(params)
            upd, opt_state = self.opt.update(g, opt_state, params)
            return optim.apply_updates(params, upd), opt_state, l

        self._step = step_fn

    def step(self, batch, i):
        self.params, self.opt_state, loss = self._step(
            self.params, self.opt_state, batch,
            jax.random.fold_in(self.key, i), jnp.int32(i))
        jax.block_until_ready(loss)


def run_ragged(quick: bool = False, rounds: int = 3, verbose: bool = True):
    """Effective-tokens/sec: token-packed bucketed batches vs rectangular
    padding, same skewed-length corpus, same token budget per batch.

    Rectangular pads every doc to max_len (rows = budget // max_len) and
    masks the loss; packed buckets by length caps (data/pipeline.py) so
    short docs stop paying the longest row's FLOPs. Both modes train the
    identical masked objective over the identical corpus, so the gated
    quantity — ``packed_vs_rect`` = median per-round ratio of epoch
    effective tokens/sec (real tokens / wall) — isolates the padding FLOPs.
    Epochs alternate rect/packed per round, the paired-drift estimator of
    ``time_engines``.
    """
    from repro.data import pipeline

    cfg = _ragged_cfg(quick)
    n_docs = 192 if quick else 768
    max_len = 64
    budget = 1024 if quick else 2048
    docs = synthetic.lm_ragged_docs(n_docs, cfg.vocab, max_len, seed=0,
                                    skew=1.0)
    real_tokens = int(docs["lengths"].sum())

    rows = budget // max_len
    rect_batches = []
    for j in range(0, n_docs, rows):
        b = {}
        for k, v in docs.items():
            pad = np.zeros((rows,) + v.shape[1:], v.dtype)
            pad[:min(rows, n_docs - j)] = v[j:j + rows]
            b[k] = jnp.asarray(pad)
        rect_batches.append(b)
    pb = pipeline.PackedBatcher(docs, budget, seed=0)
    packed_batches = [jax.tree.map(jnp.asarray, pb.batch_fn(s))
                      for s in range(pb.steps_per_epoch)]

    def slot_util(batches):
        slots = sum(int(b["tokens"].size) for b in batches)
        return real_tokens / slots

    runners = {"rect": _RaggedRunner(cfg), "packed": _RaggedRunner(cfg)}
    epochs = {"rect": rect_batches, "packed": packed_batches}

    def epoch(mode, i0):
        t0 = time.time()
        for j, b in enumerate(epochs[mode]):
            runners[mode].step(b, i0 + j)
        return time.time() - t0

    for mode in runners:               # warmup: compiles every bucket shape
        epoch(mode, 0)
    walls = {"rect": [], "packed": []}
    for r in range(rounds):
        for mode in runners:
            walls[mode].append(epoch(mode, (r + 1) * len(epochs[mode])))
    row = {
        "rect_tok_s": real_tokens / float(np.min(walls["rect"])),
        "packed_tok_s": real_tokens / float(np.min(walls["packed"])),
        "packed_vs_rect": float(np.median(
            [a / b for a, b in zip(walls["rect"], walls["packed"])])),
        "slot_util_rect": slot_util(rect_batches),
        "slot_util_packed": slot_util(packed_batches),
        "real_tokens": real_tokens,
    }
    if verbose:
        print(f"{'ragged_lm':20s} pack: rect {row['rect_tok_s']:9.0f} tok/s "
              f"(util {row['slot_util_rect']:.2f})  packed "
              f"{row['packed_tok_s']:9.0f} tok/s "
              f"(util {row['slot_util_packed']:.2f})  "
              f"packed/rect {row['packed_vs_rect']:.2f}x")
    jax.clear_caches()
    gc.collect()
    return row


# minimum packed/rect effective-tokens/sec the ragged cell must show —
# the PR 8 acceptance floor, checked in ABSOLUTE terms (it is already a
# same-host paired ratio) on top of the drift check vs the snapshot
RAGGED_FLOOR = 1.2


def check_ragged(row: dict, baseline_path: str,
                 tolerance_cell: float = 1.5) -> list:
    """Gate the ragged cell: absolute RAGGED_FLOOR + drift vs the
    snapshot's ``ragged_quick`` row (absent in pre-PR8 snapshots: floor
    only)."""
    failures = []
    r = row["packed_vs_rect"]
    status = "FAIL" if r < RAGGED_FLOOR else "ok"
    print(f"  gate {'ragged_lm':20s} packed/rect: {r:.2f}x "
          f"(floor {RAGGED_FLOOR}x) [{status}]")
    if r < RAGGED_FLOOR:
        failures.append(f"ragged_lm: packed/rect effective tokens/sec "
                        f"{r:.2f}x below the {RAGGED_FLOOR}x floor")
    with open(baseline_path) as f:
        base = json.load(f)
    b = base.get("ragged_quick")
    if b and "packed_vs_rect" in b:
        drift = b["packed_vs_rect"] / r
        status = "FAIL" if drift > tolerance_cell else "ok"
        print(f"  gate {'ragged_lm':20s} drift: baseline "
              f"{b['packed_vs_rect']:.2f}x now {r:.2f}x  "
              f"drift {drift:.2f} [{status}]")
        if drift > tolerance_cell:
            failures.append(
                f"ragged_lm: packed/rect fell {b['packed_vs_rect']:.2f}x "
                f"-> {r:.2f}x (drift {drift:.2f} > {tolerance_cell})")
    return failures


# ---------------------------------------------------------------------------
# devices axis: sharded vs single-device step times (PR 9)
# ---------------------------------------------------------------------------

# device counts the matrix sweeps (intersected with what the host offers;
# CI forces 8 CPU devices via --xla_force_host_platform_device_count)
DEVICE_COUNTS = (1, 2, 4, 8)
# engines the devices axis prices (stepwise is the reference engine, not a
# production path — pricing it per device count would double the runtime)
DEVICE_ENGINES = ("scheduled", "fused")


class _ShardedRunner:
    """A _Runner twin whose step runs under shard_map on a d-device mesh
    (launch/steps.py::make_sharded_train_step — batch sharded over "data",
    params replicated, grads psum'd exactly)."""

    def __init__(self, kind, cfg, batch, seq, n_batches, n_devices):
        from repro.configs import adapters
        from repro.distributed.sharding import strip
        from repro.launch import mesh as mesh_mod
        from repro.launch import steps as steps_mod

        self.key = jax.random.PRNGKey(0)
        self.params = strip(adapters.init_params(kind, self.key, cfg))
        self.opt = optim.chain(optim.clip_by_global_norm(1.0),
                               optim.adamw(1e-3))
        self.opt_state = self.opt.init(self.params)
        bf = _batch_fn(kind, cfg, batch, seq)
        self.batches = [jax.tree.map(jnp.asarray, bf(i))
                        for i in range(n_batches)]
        mesh = mesh_mod.make_data_mesh(n_devices)
        self._step = jax.jit(steps_mod.make_sharded_train_step(
            kind, cfg, self.opt, mesh))

    def step(self, i):
        b = self.batches[i % len(self.batches)]
        self.params, self.opt_state, loss = self._step(
            self.params, self.opt_state, b, jnp.int32(i),
            jax.random.fold_in(self.key, i))
        jax.block_until_ready(loss)

    def hlo_flops(self, i=0):
        """Per-device FLOPs of the lowered step (launch/hlo_cost.py on the
        HLO text — the shard_map body carries LOCAL shapes, so this is the
        roofline model's per-device count, machine-independent). None when
        the analyzer can't parse the module (best-effort)."""
        try:
            from repro.launch.hlo_cost import analyze_hlo
            b = self.batches[i % len(self.batches)]
            text = self._step.lower(
                self.params, self.opt_state, b, jnp.int32(i),
                jax.random.fold_in(self.key, i)).compile().as_text()
            return float(analyze_hlo(text).flops)
        except Exception:
            return None


def _devices_cells(quick: bool):
    """The devices axis prices the two LM families (the acceptance kinds
    with the heaviest recurrences); batch sizes divide every swept d."""
    cells = _cells(quick)
    return {k: cells[k] for k in ("lstm_lm", "xlstm")}


def time_devices(kind, cfg_fn, case, batch, seq, steps, n_devices,
                 warmup=2):
    """Paired sharded-vs-single step times for one (cell, engine, d).

    Same drift-cancelling estimator as ``time_engines``: both runners are
    built up front and stepped in interleaved rounds, the reported ratio is
    the median of per-round single/sharded ratios (> 1 means the sharded
    step is faster). On a forced-device CPU host all "devices" share the
    same cores, so the ratio prices shard_map OVERHEAD (it hovers near or
    below 1); on real multi-chip meshes it prices scaling. The gate checks
    drift of this paired ratio, not absolute scaling."""
    rows = {}
    for eng in DEVICE_ENGINES:
        cfg = cfg_fn(case, eng)
        single = _Runner(kind, cfg, batch, seq, warmup + steps)
        sharded = _ShardedRunner(kind, cfg, batch, seq, warmup + steps,
                                 n_devices)
        for i in range(warmup):
            single.step(i)
            sharded.step(i)
        t_single, t_sharded = [], []
        for i in range(warmup, warmup + steps):
            t0 = time.time()
            single.step(i)
            t1 = time.time()
            sharded.step(i)
            t2 = time.time()
            t_single.append(t1 - t0)
            t_sharded.append(t2 - t1)
        rows[eng] = {
            "single_ms": float(np.min(t_single) * 1e3),
            "sharded_ms": float(np.min(t_sharded) * 1e3),
            "sharded_vs_single": float(np.median(
                [a / b for a, b in zip(t_single, t_sharded)])),
            "hlo_flops_per_device": sharded.hlo_flops(),
        }
        del single, sharded
        jax.clear_caches()
        gc.collect()
    return rows


def run_devices(quick: bool = False, verbose: bool = True):
    """The devices-axis matrix: {cell: {engine: {str(d): row}}} over the
    host's available power-of-two device counts, plus the roofline check —
    per-device HLO FLOPs at d devices should track flops(1)/d (the batch
    work splits; Phase-A NR matmuls and the scans are batch-parallel)."""
    avail = len(jax.devices())
    counts = [d for d in DEVICE_COUNTS if d <= avail]
    steps = 4 if quick else 8
    out = {}
    for name, (kind, cfg_fn, B, S, _) in _devices_cells(quick).items():
        B = max(B, max(counts))
        out[name] = {eng: {} for eng in DEVICE_ENGINES}
        for d in counts:
            rows = time_devices(kind, cfg_fn, "case3", B, S, steps, d)
            for eng, row in rows.items():
                out[name][eng][str(d)] = row
                if verbose:
                    fl = row["hlo_flops_per_device"]
                    f1 = out[name][eng].get("1", {}).get(
                        "hlo_flops_per_device")
                    frac = (f" flops/dev {fl / f1:.2f}x of 1-dev "
                            f"(roofline {1 / d:.2f})"
                            if fl and f1 else "")
                    print(f"{name:20s} {eng:9s} d={d}: single "
                          f"{row['single_ms']:8.1f} ms  sharded "
                          f"{row['sharded_ms']:8.1f} ms  single/sharded "
                          f"{row['sharded_vs_single']:.2f}x{frac}")
    return out


def check_devices(dev: dict, baseline_path: str,
                  tolerance_cell: float = 1.5) -> list:
    """Gate the devices axis: drift of the paired single/sharded ratio per
    (cell, engine, d) vs the snapshot's ``devices_quick`` section. Absent
    sections (pre-PR9 snapshots) or cells skip, never fail. Forced CPU
    devices share cores, so only drift — a shard_map path regression —
    is gated, not absolute scaling."""
    with open(baseline_path) as f:
        base = json.load(f)
    base_dev = base.get("devices_quick") or {}
    if not base_dev:
        print("  (baseline has no devices_quick section — devices gate "
              "records only)")
        return []
    failures = []
    for name, by_eng in dev.items():
        for eng, by_d in by_eng.items():
            for d, row in by_d.items():
                b = base_dev.get(name, {}).get(eng, {}).get(d)
                if not b or "sharded_vs_single" not in b:
                    continue
                drift = b["sharded_vs_single"] / row["sharded_vs_single"]
                status = "FAIL" if drift > tolerance_cell else "ok"
                print(f"  gate {name:20s} {eng} d={d} [sharded]: baseline "
                      f"{b['sharded_vs_single']:.2f}x now "
                      f"{row['sharded_vs_single']:.2f}x  drift "
                      f"{drift:.2f} [{status}]")
                if drift > tolerance_cell:
                    failures.append(
                        f"{name}/{eng}/d={d}: single/sharded step ratio "
                        f"fell {b['sharded_vs_single']:.2f}x -> "
                        f"{row['sharded_vs_single']:.2f}x (drift "
                        f"{drift:.2f} > tolerance {tolerance_cell})")
    return failures


# ---------------------------------------------------------------------------
# matrix + snapshot
# ---------------------------------------------------------------------------


def run_matrix(quick: bool = False, cases=CASES, verbose: bool = True):
    out = {}
    cells = dict(_cells(quick))
    cells["lstm_lm_ptb_large"] = _acceptance_cell(quick)
    cells["nmt_iwslt"] = _iwslt_cell(quick)
    for name, (kind, cfg_fn, B, S, steps) in cells.items():
        run_cases = ("case3",) if name in ACCEPTANCE_CELLS else cases
        out[name] = {}
        for case in run_cases:
            row = time_engines(kind, cfg_fn, case, B, S, steps)
            out[name][case] = row
            if verbose:
                print(f"{name:20s} {case}: stepwise {row['stepwise']:8.1f} ms"
                      f"  scheduled {row['scheduled']:8.1f} ms"
                      f"  fused {row['fused']:8.1f} ms"
                      f"  ratio {row['ratio']:.2f}x"
                      f"  fused/sched {row['fused_vs_scheduled']:.2f}x")
            # drop this cell's executables/buffers before the next one —
            # long-process allocator state was measured skewing small cells
            jax.clear_caches()
            gc.collect()
    return out


def arch_ratios(cells: dict, key: str = "ratio") -> dict:
    """Per-arch engine speedup: geometric mean over that arch's case cells
    (individual ~40-400 ms cells carry a few % host noise; the per-arch
    aggregate is the stable quantity)."""
    out = {}
    for name, by_case in cells.items():
        rs = [row[key] for row in by_case.values() if key in row]
        if rs:
            out[name] = float(np.exp(np.mean(np.log(rs))))
    return out


def snapshot(tag: str, out_path: str, quick: bool = False) -> dict:
    cells = run_matrix(quick=quick)
    snap = {
        "tag": tag,
        "backend": jax.default_backend(),
        "impl": "xla",
        "quick": bool(quick),
        "cells": cells,
        # scheduled/stepwise per arch (geomean over cases): the headline
        # "no slower on any recurrent arch" number
        "arch_ratios": arch_ratios(cells),
        # scheduled/fused per arch: the value of the fused Phase-B pass
        "fused_arch_ratios": arch_ratios(cells, "fused_vs_scheduled"),
        # token-packed vs rectangular effective tokens/sec (PR 8)
        "ragged": run_ragged(quick=quick),
        # sharded-vs-single step times per device count (PR 9); on a
        # 1-device host this is just the d=1 overhead row
        "devices": run_devices(quick=quick),
    }
    if not quick:
        # the CI gate runs --quick, whose smaller geometries have
        # legitimately different ratios — record a quick-mode baseline
        # alongside so the gate compares like with like
        print("\nquick-mode matrix (CI gate baseline):")
        snap["quick_cells"] = run_matrix(quick=True)
        snap["quick_arch_ratios"] = arch_ratios(snap["quick_cells"])
        snap["ragged_quick"] = run_ragged(quick=True)
        snap["devices_quick"] = run_devices(quick=True)
    else:
        snap["ragged_quick"] = snap["ragged"]
        snap["devices_quick"] = snap["devices"]
    with open(out_path, "w") as f:
        json.dump(snap, f, indent=1, default=float)
    print(f"\nsnapshot {tag} -> {out_path}")
    for name in snap["arch_ratios"]:
        print(f"  {name:20s} scheduled {snap['arch_ratios'][name]:.2f}x"
              f"  fused/sched {snap['fused_arch_ratios'].get(name, 1.0):.2f}x")
    return snap


# ---------------------------------------------------------------------------
# CI perf-regression gate
# ---------------------------------------------------------------------------


def latest_baseline(root: str) -> str:
    """Path of the most recent committed ``BENCH_*.json`` snapshot, or "".

    "Latest" = highest numeric PR tag (BENCH_PR2 < BENCH_PR10); snapshots
    with non-numeric tags sort before any numeric one, ties by mtime.
    """
    def order(path):
        m = re.search(r"BENCH_\D*(\d+)\.json$", os.path.basename(path))
        return (int(m.group(1)) if m else -1, os.path.getmtime(path))

    paths = glob.glob(os.path.join(root, "BENCH_*.json"))
    return max(paths, key=order) if paths else ""


def check_regression(cells: dict, baseline_path: str,
                     tolerance_cell: float = 1.5,
                     tolerance_arch: float = 1.25,
                     quick: bool = True) -> list:
    """Compare engine ratios against a committed snapshot.

    The gated quantities are the MEDIAN PAIRED RATIOS — both
    ``ratio`` (stepwise/scheduled) and ``fused_vs_scheduled``
    (scheduled/fused, covering the fused cells of every arch incl. the
    PR5 xlstm sLSTM kernel): machine-portable because both engines of a
    pair run interleaved on the same host. Quick runs compare against the
    snapshot's ``quick_cells`` (same geometries; pre-PR3 snapshots fall
    back to the full cells with a warning). Two checks per ratio, both
    measured-noise-calibrated (module docstring): per arch x case at
    ``tolerance_cell`` (catches a cell collapse) and per-arch geomean over
    cases at ``tolerance_arch`` (catches a broad slowdown; single-cell
    paired medians swing ~1.25x run-to-run at quick sizes, the geomean
    does not). Two noise guards, both measured: the per-cell
    ``fused_vs_scheduled`` check only applies where the baseline's paired
    step times sit above the ~150 ms stability floor (the fused ratio on
    the ~20-50 ms quick cells was observed swinging 1.5-3x run-to-run —
    sub-floor cells are still covered by the per-arch geomean), and a
    "geomean" over a single common case is really a single cell, so it
    gates at ``tolerance_cell`` rather than ``tolerance_arch``.
    Cells/cases absent from the baseline are skipped (new archs don't
    fail the gate). Returns a list of failure strings (empty = pass).
    """
    with open(baseline_path) as f:
        base = json.load(f)
    base_cells = base.get("quick_cells") if quick else base.get("cells")
    if quick and not base_cells:
        print("  (baseline has no quick_cells — comparing against its "
              "full-size cells; expect larger legitimate drift)")
        base_cells = base.get("cells")
    base_cells = base_cells or {}
    gated = tuple(key for _, _, key in RATIO_PAIRS)
    stable_ms = 150.0            # per-cell fused gating floor (docstring)
    failures = []
    for name, by_case in cells.items():
        for case, row in by_case.items():
            b = base_cells.get(name, {}).get(case)
            for key in gated:
                if not b or key not in b or key not in row:
                    continue
                if key == "fused_vs_scheduled" and min(
                        b.get("scheduled", 0.0),
                        b.get("fused", 0.0)) < stable_ms:
                    continue
                drift = b[key] / row[key]
                status = "FAIL" if drift > tolerance_cell else "ok"
                print(f"  gate {name:20s} {case} [{key}]: "
                      f"baseline {b[key]:.2f}x now {row[key]:.2f}x  "
                      f"drift {drift:.2f} [{status}]")
                if drift > tolerance_cell:
                    failures.append(
                        f"{name}/{case}: {key} engine ratio fell "
                        f"{b[key]:.2f}x -> {row[key]:.2f}x "
                        f"(drift {drift:.2f} > tolerance {tolerance_cell})")
    # geomeans over the SAME case set on both sides — a case present on
    # only one side (new case added / baseline predates it) is excluded,
    # never a spurious failure
    common = {n: sorted(set(by_case) & set(base_cells.get(n, {})))
              for n, by_case in cells.items()}
    for key in gated:
        cur_arch = arch_ratios({n: {c: cells[n][c] for c in cs}
                                for n, cs in common.items() if cs}, key)
        base_arch = arch_ratios({n: {c: base_cells[n][c] for c in cs}
                                 for n, cs in common.items() if cs}, key)
        for name, br in base_arch.items():
            if name not in cur_arch:
                continue
            # a "geomean" over one common case is a single cell — it
            # carries single-cell noise, so it gates at tolerance_cell
            tol = tolerance_arch if len(common[name]) > 1 else tolerance_cell
            drift = br / cur_arch[name]
            status = "FAIL" if drift > tol else "ok"
            print(f"  gate {name:20s} geomean [{key}]: baseline {br:.2f}x "
                  f"now {cur_arch[name]:.2f}x  drift {drift:.2f} [{status}]")
            if drift > tol:
                failures.append(
                    f"{name} (geomean over cases): {key} engine ratio fell "
                    f"{br:.2f}x -> {cur_arch[name]:.2f}x "
                    f"(drift {drift:.2f} > tolerance {tol})")
    return failures


def main(quick: bool = False, check: bool = True, out: str = "",
         tolerance_cell: float = 1.5, tolerance_arch: float = 1.25,
         devices_only: bool = False) -> dict:
    if devices_only:
        cells, ragged = {}, None
    else:
        cells = run_matrix(quick=quick)
        ragged = run_ragged(quick=quick)
    # the devices axis needs >1 host device to say anything beyond the d=1
    # overhead row; always run it when asked explicitly (--devices-only)
    dev = (run_devices(quick=quick)
           if devices_only or len(jax.devices()) > 1 else {})
    result = {"backend": jax.default_backend(), "quick": bool(quick),
              "n_devices": len(jax.devices()),
              "cells": cells, "arch_ratios": arch_ratios(cells),
              "fused_arch_ratios": arch_ratios(cells, "fused_vs_scheduled"),
              "ragged": ragged, "devices": dev}
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1, default=float)
        print(f"engine matrix -> {out}")
    if quick and check:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        baseline = latest_baseline(root)
        if not baseline:
            print("perf gate: no BENCH_*.json baseline at repo root, skipped")
        else:
            print(f"\nperf gate vs {os.path.basename(baseline)} "
                  f"(tolerance {tolerance_cell}x per cell / "
                  f"{tolerance_arch}x per-arch geomean):")
            failures = []
            if not devices_only:
                failures += check_regression(cells, baseline, tolerance_cell,
                                             tolerance_arch, quick=True)
                failures += check_ragged(ragged, baseline, tolerance_cell)
            if dev:
                failures += check_devices(dev, baseline, tolerance_cell)
            if failures:
                for msg in failures:
                    print(f"PERF REGRESSION: {msg}", file=sys.stderr)
                sys.exit(1)
            print("perf gate: pass")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the --quick perf-regression gate")
    ap.add_argument("--out", default="",
                    help="also write the matrix JSON here (CI artifact)")
    ap.add_argument("--tolerance-cell", type=float, default=1.5,
                    help="allowed baseline/current paired-ratio drift per "
                         "arch x case cell")
    ap.add_argument("--tolerance-arch", type=float, default=1.25,
                    help="allowed drift of the per-arch geomean over cases")
    ap.add_argument("--devices-only", action="store_true",
                    help="run (and gate) only the devices-axis matrix — the "
                         "CI distributed job's sharded-vs-single check")
    args = ap.parse_args()
    main(quick=args.quick, check=not args.no_check, out=args.out,
         tolerance_cell=args.tolerance_cell,
         tolerance_arch=args.tolerance_arch,
         devices_only=args.devices_only)
