"""Table 1: PTB language modelling — perplexity + FP/BP/WG speedup.

Scaled-down Zaremba-medium (same structure, reduced width for CPU): trains
under baseline / NR+ST / NR+RH+ST and reports validation perplexity +
wall-clock, plus the per-phase (FP / BP+WG) matmul speedup measured in
isolation at the real Zaremba-medium gate-matmul shape.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import optim
from repro.core import masks, sparse_matmul as sm
from repro.data import synthetic
from repro.models import lstm_lm


def _cfg(mode: str, hidden=650, vocab=2000, engine="scheduled"):
    rate = 0.5
    if mode == "baseline":
        plan = common.plan_random(rate, sites=("embed", "nr", "out"))
    elif mode == "nr_st":
        # block=2 divides the paper's true width (650) and the quick width
        plan = common.plan_structured(rate, sites=("embed", "nr", "out"),
                                      block=2)
    else:  # nr_rh_st
        plan = common.plan_structured(rate, sites=("embed", "nr", "rh", "out"),
                                      block=2)
    return lstm_lm.LSTMLMConfig(vocab=vocab, embed=hidden, hidden=hidden,
                                num_layers=2, plan=plan, engine=engine)


def run_mode(mode: str, steps: int, batch=20, seq=35, hidden=650,
             engine="scheduled"):
    cfg = _cfg(mode, hidden=hidden, engine=engine)
    key = jax.random.PRNGKey(0)
    params = lstm_lm.init_params(key, cfg)
    opt = optim.chain(optim.clip_by_global_norm(5.0), optim.sgd(0.7))
    opt_state = opt.init(params)
    stream = synthetic.lm_stream(cfg.vocab, 400_000, seed=1)
    data = list(synthetic.token_batches(stream[:300_000], batch, seq))
    val = next(synthetic.token_batches(stream[300_000:], batch, seq))

    @jax.jit
    def step_fn(params, opt_state, b, key):
        l, g = jax.value_and_grad(lambda p: lstm_lm.loss_fn(
            p, {"tokens": b[0], "labels": b[1]}, cfg, drop_key=key))(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return optim.apply_updates(params, upd), opt_state, l

    params, loss, ms = common.train_and_time(
        step_fn, lambda i: jax.tree.map(jnp.asarray, data[i % len(data)]),
        params, opt_state, key, steps)
    ppl = lstm_lm.perplexity(params, jnp.asarray(val[0]),
                             jnp.asarray(val[1]), cfg)
    return common.RunResult(mode, ppl, "val_ppl", ms, loss,
                            dropout_plan=cfg.plan.to_dict(),
                            engine=cfg.engine)


def phase_speedups(rate=0.5, B=700, H=650, N=2600, block=2, n=10):
    """FP / BP / WG matmul speedups at the true Zaremba-medium gate shape."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, H))
    w = jax.random.normal(jax.random.fold_in(key, 1), (H, N)) / H ** 0.5
    dy = jax.random.normal(jax.random.fold_in(key, 2), (B, N))
    kb = masks.sample_keep_blocks(key, H, rate, block)
    m = masks.keep_blocks_to_mask(kb, H, block)
    ids = masks.keep_blocks_to_unit_ids(kb, block)

    def t(f, *a):
        jax.block_until_ready(f(*a))
        t0 = time.time()
        for _ in range(n):
            out = f(*a)
        jax.block_until_ready(out)
        return (time.time() - t0) / n

    # FP: dense-masked vs compacted
    fp_r = t(jax.jit(lambda x, w: (x * m) @ w), x, w)
    fp_s = t(jax.jit(lambda x, w: sm.sdrop_matmul(
        x, w, kb, rate=rate, block_size=block)), x, w)
    # BP: dx = dy @ w.T (masked) vs compact columns only
    bp_r = t(jax.jit(lambda dy, w: (dy @ w.T) * m), dy, w)
    bp_s = t(jax.jit(lambda dy, w: dy @ jnp.take(w, ids, 0).T), dy, w)
    # WG: dW = x.T @ dy (full rows) vs kept rows only
    wg_r = t(jax.jit(lambda x, dy: (x * m).T @ dy), x, dy)
    wg_s = t(jax.jit(lambda x, dy: jnp.take(x, ids, 1).T @ dy), x, dy)
    return fp_r / fp_s, bp_r / bp_s, wg_r / wg_s


def main(steps: int = 25, quick: bool = False):
    print("=" * 72)
    print("Table 1 — PTB LM (Zaremba-medium geometry, synthetic stream)")
    print("=" * 72)
    hidden = 256 if quick else 650     # full mode = the paper's true width
    results = [run_mode(m, steps, hidden=hidden, engine=e)
               for m in ("baseline", "nr_st", "nr_rh_st")
               for e in ("stepwise", "scheduled")]
    print(common.speedup_table(results))
    print(common.engine_ratio_lines(results))
    fp, bp, wg = phase_speedups()
    print(f"\nper-phase matmul speedup at true medium gate shape "
          f"(rate .5): FP {fp:.2f}x  BP {bp:.2f}x  WG {wg:.2f}x "
          f"(paper: 1.66/1.10/1.57)")
    return {"results": [r.__dict__ for r in results],
            "phase_speedup": {"FP": fp, "BP": bp, "WG": wg}}


if __name__ == "__main__":
    main()
