"""Request scheduler: slot admission / eviction for continuous batching.

Requests arrive with ragged prompt lengths and per-request token budgets.
The scheduler owns a FIFO queue plus the slot table; the engine owns the
device state. Two refill policies:

  * ``"continuous"`` — admit whenever a slot is free: a request hitting
    EOS/budget is evicted at the next chunk boundary and its slot refills
    immediately, so short requests never hold the batch hostage;
  * ``"batch"`` — admit only when ALL slots are free: the rectangular
    fixed-slot baseline (every group decodes until its LONGEST member
    finishes), kept as the comparison arm ``benchmarks/serving.py``
    measures continuous batching against.

``serve()`` drives the admit -> decode-chunk -> evict cycle to completion.
Determinism contract (asserted by tests/test_scheduler.py): under greedy
decoding, a request's output depends only on its own prompt — slots are
independent — so the same request set produces identical per-request
outputs under ANY arrival order or slot assignment.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

POLICIES = ("continuous", "batch")


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens + a new-token budget."""
    rid: int
    prompt: np.ndarray            # (len,) int32, len >= 1
    max_new: int                  # token budget (EOS may stop earlier)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new < 1:
            raise ValueError(f"request {self.rid}: max_new must be >= 1")


class Scheduler:
    """Slot table + FIFO admission queue.

    Invariants (asserted in tests): a request occupies at most one slot;
    a slot is reused only after eviction; every submitted request is
    admitted exactly once and eventually evicted.
    """

    def __init__(self, num_slots: int, policy: str = "continuous"):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.num_slots = num_slots
        self.policy = policy
        self.queue: deque = deque()
        self.slot_rid: List[Optional[int]] = [None] * num_slots
        self._seen: set = set()
        self.admitted = 0
        self.evicted = 0

    # -- queue -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.rid in self._seen:
            raise ValueError(f"duplicate rid {req.rid}")
        self._seen.add(req.rid)
        self.queue.append(req)

    # -- slots -----------------------------------------------------------

    @property
    def free_slots(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_rid) if r is None]

    @property
    def busy_slots(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_rid) if r is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.busy_slots)

    def admit(self) -> List[Tuple[int, Request]]:
        """Pop queued requests FIFO into free slots (policy-gated)."""
        free = self.free_slots
        if self.policy == "batch" and len(free) < self.num_slots:
            return []
        out = []
        for slot in free:
            if not self.queue:
                break
            req = self.queue.popleft()
            self.slot_rid[slot] = req.rid
            self.admitted += 1
            out.append((slot, req))
        return out

    def evict(self, slot: int) -> int:
        rid = self.slot_rid[slot]
        if rid is None:
            raise ValueError(f"slot {slot} is not busy")
        self.slot_rid[slot] = None
        self.evicted += 1
        return rid


def serve(engine, requests, *, chunk: Optional[int] = None,
          policy: str = "continuous", seed: int = 0) -> Dict[int, np.ndarray]:
    """Serve ``requests`` to completion on ``engine``.

    Admission prefill is batched per admitted group (masked ragged replay,
    ``DecodeEngine.admit``); decode advances all active slots ``chunk``
    tokens per device dispatch; finished slots are evicted at chunk
    boundaries and refilled (policy "continuous") or held until the whole
    batch drains (policy "batch"). Returns ``{rid: generated tokens}``
    (the EOS token, if emitted, is included).
    """
    sched = Scheduler(engine.batch, policy=policy)
    engine.reset(seed=seed)
    outputs: Dict[int, list] = {}
    for r in requests:
        sched.submit(r)
        outputs[r.rid] = []
    guard = 0
    while sched.has_work:
        admitted = sched.admit()
        if admitted:
            engine.admit([s for s, _ in admitted],
                         [r.prompt for _, r in admitted],
                         [r.max_new for _, r in admitted])
        toks, n_gen, active = engine.decode_chunk(chunk)
        progressed = bool(admitted)
        for slot in sched.busy_slots:
            k = int(n_gen[slot])
            if k:
                outputs[sched.slot_rid[slot]].extend(toks[slot, :k].tolist())
                progressed = True
            if not active[slot]:
                sched.evict(slot)
        guard = 0 if progressed else guard + 1
        if guard > 2:
            raise RuntimeError(
                "serve loop stalled: no admission, generation, or eviction "
                f"for {guard} chunks (queue={len(sched.queue)}, "
                f"busy={sched.busy_slots})")
    assert sched.evicted == sched.admitted == len(outputs)
    return {rid: np.asarray(v, np.int32) for rid, v in outputs.items()}
