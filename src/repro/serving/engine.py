"""Continuous-batching decode engine with an ON-DEVICE decode loop.

The pre-PR6 engine was a host loop: one jitted single-token step per
generated token — one dispatch + one host sync per token, which is where
small-model serving throughput dies. This engine keeps three structural
upgrades:

  * **on-device decode loop** — one jitted ``lax.while_loop`` advances up
    to ``chunk`` tokens for every slot, carrying ``(state, last-token,
    pos, budget, active)`` and writing sampled tokens into a preallocated
    ``(B, chunk)`` device buffer: one dispatch per CHUNK. The loop exits
    early once every slot is inactive, so a nearly-drained batch does not
    pay for the full chunk (tokens/sec matrix: ``benchmarks/serving.py``,
    gated in CI).
  * **slot admission / eviction** — requests with ragged prompt lengths
    and token budgets are admitted into free slots (``admit``: batched
    masked-replay prefill via ``serving/prefill.py``, state rows scattered
    into the slot indices), decode until EOS/budget, and report
    ``active=False`` so the scheduler (``serving/scheduler.py``) evicts
    and refills the slot immediately — continuous batching.
  * **sharded engine state** — decode-state leaves are placed on the mesh
    by their logical axes (slots/batch over ("pod", "data"), kv-heads over
    "model") through ``distributed/sharding.py`` rules, so the same engine
    runs a multi-device CPU mesh in CI and a pod mesh unchanged.

Cache kinds: transformer paged-lite KV (one contiguous region per slot)
and recurrent O(1) state (xlstm / ssm — the long_500k path). Per-slot
ragged POSITIONS require the recurrent path: the KV ``decode_step``
consumes a single scalar write position, so transformers serve through
the same engine in rectangular mode (uniform positions across slots).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import adapters
from repro.configs.base import ArchSpec
from repro.distributed import sharding as shd
from repro.serving import prefill as prefill_mod

I32 = jnp.int32


def sample_logits(key, logits, *, temperature: float = 1.0,
                  top_k: int = 0) -> jax.Array:
    """logits: (B, 1, V) -> token ids (B, 1).

    The top-k mask uses ``finfo.min`` of the logits dtype (not a hard-coded
    constant): masked entries stay finite, so even the all-masked edge
    (e.g. a constant row) yields a valid in-vocab sample rather than NaN.
    """
    lg = logits[:, 0, :].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1)[:, None].astype(I32)
    lg = lg / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(lg, top_k)
        lg = jnp.where(lg < vals[:, -1:], jnp.finfo(lg.dtype).min, lg)
    return jax.random.categorical(key, lg)[:, None].astype(I32)


def _bucket(n: int, quantum: int = 8) -> int:
    """Round a ragged replay length up to a shape bucket so the jitted
    replay scan compiles O(#buckets) times, not O(#distinct lengths)."""
    return max(quantum, -(-n // quantum) * quantum)


@dataclasses.dataclass
class DecodeEngine:
    """Slot-batched decode engine over a shared cache/state.

    ``eos_id < 0`` disables EOS stopping (fixed-length generation).
    ``mesh``/``rules`` shard the engine state; without a mesh everything
    stays single-device (CPU tests).
    """
    spec: ArchSpec
    cfg: Any
    params: Any
    max_seq: int
    batch: int
    rules: Any = None
    mesh: Any = None
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = -1
    chunk: int = 16
    chunks_run: int = 0          # host-visible dispatch counter (bench/tests)

    def __post_init__(self):
        if self.mesh is not None and self.rules is None:
            self.rules = shd.rules_for_mesh(self.mesh)
        self.state = self._fresh_state(self.batch)
        B = self.batch
        self.tok = jnp.zeros((B, 1), I32)       # last token per slot
        self.pos = jnp.zeros((B,), I32)         # tokens consumed per slot
        self.gen_left = jnp.zeros((B,), I32)    # remaining token budget
        self.active = jnp.zeros((B,), bool)
        self._key = jax.random.PRNGKey(0)
        self._loops: dict = {}
        self._scatter = jax.jit(
            lambda full, p, idx: jax.tree.map(
                lambda f, q: f.at[:, idx].set(q.astype(f.dtype)), full, p),
            donate_argnums=(0,))
        decode = adapters.decode_fn(self.spec)
        cfg, rules = self.cfg, self.rules

        def step(params, state, tokens, pos, key):
            logits, state = decode(params, cfg, state, tokens, pos,
                                   rules=rules)
            nxt = sample_logits(key, logits, temperature=self.temperature,
                                top_k=self.top_k)
            return nxt, state

        self._step_fn = jax.jit(step, donate_argnums=(1,))
        self._replay_fn = jax.jit(
            lambda params, state, toks, lens: prefill_mod.replay_prefill(
                self.spec, cfg, params, state, toks, lens, rules=rules),
            donate_argnums=(1,))

    # ------------------------------------------------------------------
    # state lifecycle
    # ------------------------------------------------------------------

    def _fresh_state(self, batch: int, shard: bool = True):
        state = adapters.init_decode_state(self.spec, self.cfg, batch,
                                           self.max_seq)
        if shard and self.mesh is not None:
            state = shd.shard_put(
                state, adapters.decode_state_axes(self.spec, self.cfg),
                self.rules, self.mesh)
        return state

    def reset(self, seed: int = 0) -> None:
        """Clear every slot (fresh state, all inactive) for a new trace."""
        self.state = self._fresh_state(self.batch)
        B = self.batch
        self.tok = jnp.zeros((B, 1), I32)
        self.pos = jnp.zeros((B,), I32)
        self.gen_left = jnp.zeros((B,), I32)
        self.active = jnp.zeros((B,), bool)
        self._key = jax.random.PRNGKey(seed)
        self.chunks_run = 0

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else (
            contextlib.nullcontext())

    # ------------------------------------------------------------------
    # rectangular prefill (compat API — the scheduler path uses admit())
    # ------------------------------------------------------------------

    def prefill(self, batch) -> None:
        f = adapters.prefill_fn(self.spec)
        with self._mesh_ctx():
            _, self.state = f(self.params, batch, self.cfg, self.state,
                              rules=self.rules)

    # ------------------------------------------------------------------
    # slot admission (continuous batching)
    # ------------------------------------------------------------------

    def admit(self, slots: Sequence[int], prompts: Sequence,
              budgets: Sequence[int]) -> None:
        """Prefill newly admitted ragged prompts into free slots.

        ``prompts``: 1-D int32 token arrays (len >= 1) — the whole group
        replays BATCHED (padded to a shape bucket, per-row length masking)
        and its state rows scatter into ``slots``; each slot then holds
        ``pos = len - 1`` with the prompt's last token queued, per the
        serving/prefill.py convention.
        """
        g = len(slots)
        assert g == len(prompts) == len(budgets) and g > 0
        lens = np.array([len(p) for p in prompts], np.int64)
        if lens.min() < 1 or min(budgets) < 1:
            raise ValueError("prompts must be non-empty, budgets >= 1")
        if self.spec.kind == "transformer":
            uniform = len(set(lens.tolist())) == 1
            if bool(np.any(np.asarray(self.active))) or not uniform:
                raise NotImplementedError(
                    "per-slot ragged positions need recurrent O(1) state; "
                    "the KV decode step writes at one scalar position — "
                    "serve transformers rectangularly (all slots admitted "
                    "together with equal prompt lengths)")
        T = int(lens.max()) - 1
        part = self._fresh_state(g, shard=False)
        if T > 0:
            Tb = _bucket(T)
            toks = np.zeros((g, Tb), np.int32)
            for r, p in enumerate(prompts):
                toks[r, :lens[r] - 1] = np.asarray(p, np.int32)[:-1]
            with self._mesh_ctx():
                part = self._replay_fn(self.params, part,
                                       jnp.asarray(toks),
                                       jnp.asarray(lens - 1, I32))
        idx = jnp.asarray(np.asarray(slots, np.int32))
        # one jitted scatter for the whole state tree (vs one eager
        # dispatch per leaf — admission cost is on the serving hot path)
        self.state = self._scatter(self.state, part, idx)
        last = np.array([np.asarray(p)[-1] for p in prompts], np.int32)
        self.tok = self.tok.at[idx, 0].set(jnp.asarray(last))
        self.pos = self.pos.at[idx].set(jnp.asarray(lens - 1, I32))
        self.gen_left = self.gen_left.at[idx].set(
            jnp.asarray(np.asarray(budgets, np.int32)))
        self.active = self.active.at[idx].set(True)

    # ------------------------------------------------------------------
    # on-device decode loop
    # ------------------------------------------------------------------

    def _loop_fn(self, n: int):
        """Jitted while_loop advancing up to ``n`` tokens for every slot."""
        if n in self._loops:
            return self._loops[n]
        decode = adapters.decode_fn(self.spec)
        cfg, rules = self.cfg, self.rules
        temp, top_k, eos = self.temperature, self.top_k, self.eos_id

        def loop(params, state, tok, pos, gen_left, active, key):
            B = tok.shape[0]

            def cond(c):
                return (c[0] < n) & jnp.any(c[5])

            def body(c):
                i, state, tok, pos, gen_left, active, out = c
                # scalar decode position: identical across slots on the
                # rectangular (KV) path; ignored by the recurrent cells.
                logits, state = decode(params, cfg, state, tok,
                                       jnp.max(pos), rules=rules)
                nxt = sample_logits(jax.random.fold_in(key, i), logits,
                                    temperature=temp, top_k=top_k)
                # inactive slots freeze their token (their state rows are
                # dead until the next admission overwrites them)
                nxt = jnp.where(active[:, None], nxt, tok)
                out = out.at[:, i].set(jnp.where(active, nxt[:, 0], -1))
                act_i = active.astype(I32)
                pos = pos + act_i
                gen_left = gen_left - act_i
                active = active & (gen_left > 0) & (nxt[:, 0] != eos)
                return (i + 1, state, nxt, pos, gen_left, active, out)

            c = jax.lax.while_loop(
                cond, body,
                (jnp.int32(0), state, tok, pos, gen_left, active,
                 jnp.full((B, n), -1, I32)))
            return c[1], c[2], c[3], c[4], c[5], c[6]

        self._loops[n] = jax.jit(loop, donate_argnums=(1,))
        return self._loops[n]

    def decode_chunk(self, n: Optional[int] = None):
        """Advance every active slot by up to ``n`` tokens in ONE dispatch.

        Returns host arrays ``(tokens (B, n), n_gen (B,), active (B,))``:
        slot ``s`` generated ``tokens[s, :n_gen[s]]`` this chunk (a slot
        hitting EOS/budget mid-chunk stops there and reports
        ``active[s] = False`` so the scheduler can evict + refill it).
        """
        n = int(n or self.chunk)
        fn = self._loop_fn(n)
        self._key, sub = jax.random.split(self._key)
        prev = np.asarray(self.pos)
        with self._mesh_ctx():
            (self.state, self.tok, self.pos, self.gen_left, self.active,
             out) = fn(self.params, self.state, self.tok, self.pos,
                       self.gen_left, self.active, sub)
        self.chunks_run += 1
        return (np.asarray(out), np.asarray(self.pos) - prev,
                np.asarray(self.active))

    # ------------------------------------------------------------------
    # rectangular generation APIs
    # ------------------------------------------------------------------

    def generate(self, prompt_tokens: jax.Array, n_steps: int,
                 *, seed: int = 0, start_pos: int = 0) -> np.ndarray:
        """Greedy/sampled continuation of (B, 1) last-prompt tokens —
        the whole decode as ONE on-device loop dispatch.

        ``start_pos`` = number of tokens already in the cache/state.
        Greedy results match the per-token reference loop exactly; sampled
        paths draw per-step keys as ``fold_in(key, step)`` (the pre-PR6
        host loop split a key per step, so sampled sequences differ)."""
        B = self.batch
        self.tok = jnp.asarray(prompt_tokens, I32)
        self.pos = jnp.full((B,), start_pos, I32)
        self.gen_left = jnp.full((B,), n_steps, I32)
        self.active = jnp.ones((B,), bool)
        self._key = jax.random.PRNGKey(seed)
        out, _, _ = self.decode_chunk(n_steps)
        return out

    def generate_python(self, prompt_tokens: jax.Array, n_steps: int,
                        *, seed: int = 0, start_pos: int = 0) -> np.ndarray:
        """The pre-PR6 per-token host loop: one dispatch + one host sync
        per generated token. Kept as the paired baseline the serving
        benchmark measures the on-device loop against (and as an A/B
        reference for the loop's greedy outputs)."""
        key = jax.random.PRNGKey(seed)
        tok = jnp.asarray(prompt_tokens, I32)
        out = []
        with self._mesh_ctx():
            for t in range(n_steps):
                key, sub = jax.random.split(key)
                tok, self.state = self._step_fn(self.params, self.state,
                                                tok, start_pos + t, sub)
                out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)
