"""Batched decode engine: prefill -> step loop over a shared cache/state.

Works for both cache kinds:
  * transformer archs — paged-lite KV cache (one contiguous region per
    request slot, slot reuse on completion);
  * recurrent archs (xlstm / ssm) — O(1) state, max_seq only bounds
    positions (long_500k serves on this path).

The engine is deliberately simple (continuous batching over fixed slots) —
the scale story lives in the sharding of the cache (batch over ("pod",
"data"), kv-heads over "model"), not in scheduler cleverness.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import adapters
from repro.configs.base import ArchSpec


def sample_logits(key, logits, *, temperature: float = 1.0,
                  top_k: int = 0) -> jax.Array:
    """logits: (B, 1, V) -> token ids (B, 1)."""
    lg = logits[:, 0, :].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
    lg = lg / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(lg, top_k)
        lg = jnp.where(lg < vals[:, -1:], -1e30, lg)
    return jax.random.categorical(key, lg)[:, None].astype(jnp.int32)


@dataclasses.dataclass
class DecodeEngine:
    spec: ArchSpec
    cfg: Any
    params: Any
    max_seq: int
    batch: int
    rules: Any = None
    temperature: float = 0.0
    _step_fn: Optional[Callable] = None

    def __post_init__(self):
        self.state = adapters.init_decode_state(
            self.spec, self.cfg, self.batch, self.max_seq)
        decode = adapters.decode_fn(self.spec)
        cfg, rules = self.cfg, self.rules

        def step(params, state, tokens, pos, key):
            logits, state = decode(params, cfg, state, tokens, pos,
                                   rules=rules)
            nxt = sample_logits(key, logits, temperature=self.temperature)
            return nxt, state

        self._step_fn = jax.jit(step, donate_argnums=(1,))

    def prefill(self, batch) -> None:
        f = adapters.prefill_fn(self.spec)
        _, self.state = f(self.params, batch, self.cfg, self.state,
                          rules=self.rules)

    def generate(self, prompt_tokens: jax.Array, n_steps: int,
                 *, seed: int = 0, start_pos: int = 0) -> np.ndarray:
        """Greedy/sampled continuation of (B, 1) last-prompt tokens.

        ``start_pos`` = number of tokens already in the cache/state."""
        key = jax.random.PRNGKey(seed)
        tok = prompt_tokens
        out = []
        for t in range(n_steps):
            key, sub = jax.random.split(key)
            tok, self.state = self._step_fn(self.params, self.state, tok,
                                            start_pos + t, sub)
            out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)
