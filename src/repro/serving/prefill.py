"""Shared prompt-replay prefill: ONE helper for every cache kind.

Two ways to turn a prompt into decode state:

  * ``prompt_prefill`` (method="native") — the arch's own rectangular
    prefill through ``adapters.prefill_fn``: the transformer fills its KV
    cache in one attention pass, xlstm runs its chunkwise/scan prefill.
    Fastest, but rectangular — every row must be a full-length prompt.
  * ``replay_prefill`` — a ``lax.scan`` of ``decode_step`` over (padded)
    prompt tokens with per-row length masking, so RAGGED prompt groups
    prefill in one batched call: each row stops updating its state slice
    at its own length. Works for every kind with a decode path, and is
    the only prefill for ssm (whose forward emits features, not state).

Convention (both helpers, the engine, and both serve entry points):
prefill consumes ``prompt[:, :-1]``; decode then starts by feeding
``prompt[:, -1]`` at position ``len - 1``, which emits the logits for the
first *generated* token. (The pre-PR6 drivers each carried a copy-pasted
per-token replay loop that processed the last prompt token twice —
``launch/serve.py`` and ``examples/serve_batched.py`` now share this
module instead.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import adapters


def select_rows(old, new, keep):
    """Per-slot decode-state select: every decode-state leaf is
    ``(L, B, ...)`` with the slot/batch dim at axis 1; ``keep`` is (B,)
    bool — True rows take ``new``, False rows keep ``old``."""
    def sel(o, nw):
        m = keep.reshape((1, -1) + (1,) * (o.ndim - 2))
        return jnp.where(m, nw.astype(o.dtype), o)
    return jax.tree.map(sel, old, new)


def replay_prefill(spec, cfg, params, state, tokens, lengths=None, *,
                   rules=None, start_pos: int = 0):
    """Replay ``tokens`` (B, T) through ``decode_step``, masking ragged rows.

    ``lengths`` (B,) counts the valid replay tokens per row (default: all
    T); rows stop updating their state slice at their own length, so one
    batched scan prefills a ragged group and each row's final state equals
    a dedicated length-``lengths[b]`` replay. Returns the updated state.
    """
    decode = adapters.decode_fn(spec)
    B, T = tokens.shape
    if T == 0:
        return state
    lengths = (jnp.full((B,), T, jnp.int32) if lengths is None
               else jnp.asarray(lengths, jnp.int32))

    def body(carry, inp):
        st = carry
        tok_t, t = inp
        _, new_st = decode(params, cfg, st, tok_t[:, None], start_pos + t,
                           rules=rules)
        return select_rows(st, new_st, t < lengths), None

    state, _ = jax.lax.scan(
        body, state, (tokens.T, jnp.arange(T, dtype=jnp.int32)))
    return state


def prompt_prefill(spec, cfg, params, prompt, *, state, rules=None,
                   method: str = "auto"):
    """Rectangular prompt -> decode handoff for either cache kind.

    ``prompt``: (B, L) int32, L >= 1. Prefills ``prompt[:, :-1]`` into
    ``state`` and returns ``(state, last_tokens (B, 1), start_pos)`` —
    feed ``last_tokens`` at ``start_pos`` to generate the first new token.
    method="auto" picks the arch's native prefill where it really fills
    state (``adapters.has_native_prefill``) and the replay scan otherwise.
    """
    if method == "auto":
        method = "native" if adapters.has_native_prefill(spec) else "replay"
    body = prompt[:, :-1]
    if body.shape[1]:
        if method == "native":
            f = adapters.prefill_fn(spec)
            _, state = f(params, {"tokens": body}, cfg, state, rules=rules)
        else:
            state = jax.jit(
                lambda p, s, t: replay_prefill(spec, cfg, p, s, t,
                                               rules=rules),
                donate_argnums=(1,))(params, state, body)
    return state, prompt[:, -1:], prompt.shape[1] - 1
