"""Serving: continuous-batching server core.

scheduler (admission/eviction) -> on-device chunked decode loop (engine)
-> shared prompt-replay prefill (prefill), state sharded over the mesh.
"""
from repro.serving.engine import DecodeEngine, sample_logits
from repro.serving.prefill import prompt_prefill, replay_prefill
from repro.serving.scheduler import Request, Scheduler, serve
