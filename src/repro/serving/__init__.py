"""Serving: batched decode engine over KV caches / recurrent states."""
from repro.serving.engine import DecodeEngine, sample_logits
