"""Unified dropout-plan API: named application sites, one RNG-stream contract.

A ``DropoutPlan`` maps **named application sites** — the places a model
consumes activations through dropout (``"embed"``, ``"nr"``, ``"layer3/rh"``,
``"out"``) — to ``DropoutSpec``s. The plan is the *experiment variable*: the
model stays fixed while the plan flips the paper's pattern knob (Case I-IV,
NR/RH placement, block granularity) for every architecture family.

``plan.bind(key, step)`` returns a ``DropoutCtx`` that owns all PRNG-stream
derivation. The contract:

  * the training ``step`` is folded into ``key`` once, at bind time — every
    training step re-samples (standard dropout behaviour);
  * each site gets an independent stream by hashing its full site *name*
    (CRC-32), so there are no hand-numbered ``fold_in(key, 3)`` calls and two
    sites can never collide by accident;
  * the site's *time pattern* is applied inside the ctx: callers pass the
    index ``t`` of the arch's recurrence axis (sequence time for RNN cells,
    layer index for depth-scanned stacks) and the ctx folds it in for
    ``PER_STEP`` specs or ignores it for ``FIXED`` ones.

Site-name resolution is hierarchical: a site ``"enc/layer0/nr"`` matches an
exact plan entry first, then its last path component (``"nr"``), then a
``"*"`` wildcard, else it is inactive. The *spec* may be shared between sites
this way, but the PRNG stream is always derived from the full name — same
spec, independent masks.

Block sizes are caps, not hard requirements: when a site's feature dimension
is not divisible by ``spec.block_size`` the ctx uses the largest divisor of
the dimension that does not exceed it, so one ``--dropout case3:0.5:bs128``
override runs unchanged on a 64-wide smoke config and a 8192-wide full one.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Mapping, Optional, Tuple, Union

import jax

from repro.core import masks as _masks
from repro.core import sdrop
from repro.core.masks import TimePattern
from repro.core.sdrop import DropoutSpec, DropoutState

_INACTIVE = DropoutSpec(rate=0.0)


def site_stream(site: str) -> int:
    """Deterministic per-site stream id (stable across processes/versions)."""
    return zlib.crc32(site.encode("utf-8")) & 0x7FFFFFFF


def fit_block(spec: DropoutSpec, dim: int) -> DropoutSpec:
    """Clamp block_size to the largest divisor of ``dim`` <= the requested one."""
    bs = min(spec.block_size, dim)
    while dim % bs:
        bs -= 1
    return spec if bs == spec.block_size else spec.with_(block_size=bs)


@dataclasses.dataclass(frozen=True)
class DropoutPlan:
    """Mapping of named application sites to DropoutSpecs (hashable, frozen)."""

    sites: Union[Tuple[Tuple[str, DropoutSpec], ...], Mapping[str, DropoutSpec]] = ()

    def __post_init__(self):
        s = self.sites
        if isinstance(s, Mapping):
            s = s.items()
        s = tuple(sorted(((str(k), v) for k, v in s),
                         key=lambda kv: kv[0]))        # canonical: == / hash
        names = [name for name, _ in s]
        if len(set(names)) != len(names):
            dup = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate dropout site(s): {dup}")
        for name, spec in s:
            if not isinstance(spec, DropoutSpec):
                raise TypeError(f"site {name!r}: expected DropoutSpec, "
                                f"got {type(spec).__name__}")
        object.__setattr__(self, "sites", s)

    # -- lookup -------------------------------------------------------------

    @property
    def mapping(self) -> dict:
        return dict(self.sites)

    def spec(self, site: str) -> DropoutSpec:
        """Resolve a (possibly hierarchical) site name to its spec."""
        d = self.mapping
        if site in d:
            return d[site]
        base = site.rsplit("/", 1)[-1]
        if base in d:
            return d[base]
        if "*" in d:
            return d["*"]
        return _INACTIVE

    @property
    def any_active(self) -> bool:
        return any(spec.active for _, spec in self.sites)

    def active_sites(self) -> Tuple[str, ...]:
        return tuple(name for name, spec in self.sites if spec.active)

    # -- construction -------------------------------------------------------

    @staticmethod
    def off() -> "DropoutPlan":
        return DropoutPlan()

    @staticmethod
    def case(name: str, rate: float, block_size: int = 1, impl: str = "xla",
             sites: Tuple[str, ...] = ("*",)) -> "DropoutPlan":
        """One of the paper's Case I-IV at every named site.

            DropoutPlan.case("case3", rate=0.5, block_size=128,
                             sites=("nr", "rh"))
        """
        spec = DropoutSpec.case(name, rate, block_size=block_size, impl=impl)
        return DropoutPlan({s: spec for s in sites})

    @staticmethod
    def parse(text: str, sites: Tuple[str, ...] = ("*",)) -> "DropoutPlan":
        """Parse a CLI override like ``case3:0.5:bs128`` or ``off``.

        Grammar: ``off`` | ``case{1..4}:<rate>[:bs<int>][:<impl>]``.
        """
        text = text.strip()
        if text in ("", "off", "none"):
            return DropoutPlan.off()
        parts = text.split(":")
        case = parts[0]
        if case not in _masks.CASES:
            raise ValueError(f"unknown dropout case {case!r}; expected one of "
                             f"{sorted(_masks.CASES)} or 'off'")
        if len(parts) < 2:
            raise ValueError(f"dropout override {text!r} is missing a rate "
                             f"(e.g. '{case}:0.5')")
        rate = float(parts[1])
        if not 0.0 <= rate < 1.0:
            raise ValueError(f"dropout rate must be in [0, 1), got {rate}")
        block_size, impl = 1, "xla"
        for tok in parts[2:]:
            if tok.startswith("bs"):
                block_size = int(tok[2:])
                if block_size < 1:
                    raise ValueError(f"block size must be >= 1, got {tok!r}")
            elif tok in ("xla", "pallas"):
                impl = tok
            else:
                raise ValueError(f"unknown dropout override token {tok!r}")
        return DropoutPlan.case(case, rate, block_size=block_size, impl=impl,
                                sites=sites)

    def replace(self, site_specs: Mapping[str, DropoutSpec]) -> "DropoutPlan":
        """New plan with the given sites added/overridden (hierarchical
        names like "enc/layer0/nr" are valid keys)."""
        d = self.mapping
        d.update(site_specs)
        return DropoutPlan(d)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-serializable round-trippable description of the plan."""
        return {"sites": {name: spec.to_dict() for name, spec in self.sites}}

    @staticmethod
    def from_dict(d: dict) -> "DropoutPlan":
        return DropoutPlan({name: DropoutSpec.from_dict(sd)
                            for name, sd in d.get("sites", {}).items()})

    # -- binding ------------------------------------------------------------

    def bind(self, key: Optional[jax.Array], step=None, *,
             deterministic: bool = False) -> "DropoutCtx":
        """Bind the plan to a PRNG key for one training step.

        ``key=None`` or ``deterministic=True`` yields an eval-mode ctx whose
        states/applies are all no-ops (the explicit replacement for the old
        implicit ``drop_key is None`` convention).
        """
        if key is None or deterministic or not self.any_active:
            return DropoutCtx(plan=self, key=None)
        if step is not None:
            key = jax.random.fold_in(key, step)
        return DropoutCtx(plan=self, key=key)


@dataclasses.dataclass(frozen=True)
class DropoutCtx:
    """A plan bound to (key, step): the only source of dropout randomness."""

    plan: DropoutPlan
    key: Optional[jax.Array] = None

    @property
    def deterministic(self) -> bool:
        return self.key is None

    def spec(self, site: str) -> DropoutSpec:
        return self.plan.spec(site)

    def site_key(self, site: str, *, t=None) -> jax.Array:
        """The site's PRNG key; ``t`` indexes the site's recurrence axis."""
        if self.key is None:
            raise ValueError("site_key on a deterministic DropoutCtx")
        k = jax.random.fold_in(self.key, site_stream(site))
        if t is not None and self.spec(site).time_pattern == TimePattern.PER_STEP:
            k = jax.random.fold_in(k, t)
        return k

    def state(self, site: str, batch, dim: int, *, t=None) -> DropoutState:
        """Materialize the site's DropoutState for one application.

        ``batch`` is an int or a tuple of leading dims (random-pattern dense
        masks are shaped accordingly; structured masks ignore it).
        """
        spec = self.spec(site)
        if self.key is None or not spec.active:
            return DropoutState(spec=spec)
        spec = fit_block(spec, dim)
        shape = (batch,) if isinstance(batch, int) else tuple(batch)
        n = 1
        for s in shape:
            n *= int(s)
        st = sdrop.make_state(self.site_key(site, t=t), spec, n, dim)
        if st.dense_mask is not None and len(shape) > 1:
            st.dense_mask = st.dense_mask.reshape(*shape, dim)
        return st

    def apply(self, site: str, x: jax.Array, *, t=None) -> jax.Array:
        """Mask-multiply ``x`` at the site (for elementwise consumers)."""
        spec = self.spec(site)
        if self.key is None or not spec.active:
            return x
        st = self.state(site, tuple(x.shape[:-1]), x.shape[-1], t=t)
        return st.apply(x)


NULL_CTX = DropoutCtx(plan=DropoutPlan())
