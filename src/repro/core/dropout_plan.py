"""Unified dropout-plan API: named application sites, one RNG-stream contract.

A ``DropoutPlan`` maps **named application sites** — the places a model
consumes activations through dropout (``"embed"``, ``"nr"``, ``"layer3/rh"``,
``"out"``) — to ``DropoutSpec``s. The plan is the *experiment variable*: the
model stays fixed while the plan flips the paper's pattern knob (Case I-IV,
NR/RH placement, block granularity) for every architecture family.

``plan.bind(key, step)`` returns a ``DropoutCtx`` that owns all PRNG-stream
derivation. Sites can be consumed two ways:

  * **stepwise** — ``ctx.state(site, batch, dim, t=t)`` materializes one
    step's mask at a time (the reference path, used inside ``lax.scan``
    bodies);
  * **scheduled** — ``ctx.schedule(site, steps, batch, dim)`` samples *all*
    steps' masks in one pre-scan pass (Phase A of the two-phase recurrent
    engine) into a ``MaskSchedule``: a ``(T, nk)`` keep-block table for
    structured specs, a ``(T, B, H)`` bitmask for random ones, and a single
    broadcast row for FIXED time patterns. Row ``t`` of a schedule is
    bit-identical to ``ctx.state(..., t=t)`` — both derive the same per-step
    key — so the two consumption styles are interchangeable.

The contract:

  * the training ``step`` is folded into ``key`` once, at bind time — every
    training step re-samples (standard dropout behaviour);
  * each site gets an independent stream by hashing its full site *name*
    (CRC-32), so there are no hand-numbered ``fold_in(key, 3)`` calls and two
    sites can never collide by accident;
  * the site's *time pattern* is applied inside the ctx: callers pass the
    index ``t`` of the arch's recurrence axis (sequence time for RNN cells,
    layer index for depth-scanned stacks) and the ctx folds it in for
    ``PER_STEP`` specs or ignores it for ``FIXED`` ones.

Site-name resolution is hierarchical: a site ``"enc/layer0/nr"`` matches an
exact plan entry first, then its last path component (``"nr"``), then a
``"*"`` wildcard, else it is inactive. The *spec* may be shared between sites
this way, but the PRNG stream is always derived from the full name — same
spec, independent masks.

Block sizes are caps, not hard requirements: when a site's feature dimension
is not divisible by ``spec.block_size`` the ctx uses the largest divisor of
the dimension that does not exceed it, so one ``--dropout case3:0.5:bs128``
override runs unchanged on a 64-wide smoke config and a 8192-wide full one.

Batch sharding (the ``shard_map`` data-parallel path)
-----------------------------------------------------

When the training step runs under ``jax.shard_map`` with the batch rows
split across devices (distributed/data_parallel.py), the model code inside
each shard sees only its LOCAL rows — but the masks must match what the
single-device run would draw for those same rows. ``plan.bind(key, step,
shard=BatchShard(index, count))`` threads the shard's position through the
ctx:

  * STRUCTURED specs are batch-independent (every row drops the same
    units), so keep-block id tables come out identical on every shard —
    replicated for free, nothing to do;
  * RANDOM specs are per-row: the ctx samples the mask at the GLOBAL batch
    size (``count`` x the local rows, same key and shape as the
    single-device run — counter-based PRNG makes that bit-identical) and
    dynamic-slices this shard's row block out. Dense per-step bitmasks
    therefore shard with the batch rows they mask, row-for-row equal to
    the unsharded reference (tests/test_distributed.py asserts it for all
    three engines).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import masks as _masks
from repro.core import sdrop
from repro.core.masks import TimePattern
from repro.core.sdrop import DropoutSpec, DropoutState

_INACTIVE = DropoutSpec(rate=0.0)


@dataclasses.dataclass(frozen=True)
class BatchShard:
    """Position of one device's batch rows within the global batch.

    ``index`` is this shard's position along the (flattened) batch mesh
    axes — a traced int32 from ``lax.axis_index`` inside a ``shard_map``
    body, or a plain int. ``count`` is the static number of batch shards.
    Local row ``b`` of this shard is global row ``index * local_batch + b``:
    batches are sharded contiguously over their leading axis (the
    PartitionSpec contract of distributed/data_parallel.py).
    """

    index: object
    count: int

    def __post_init__(self):
        if int(self.count) < 1:
            raise ValueError(f"shard count must be >= 1, got {self.count}")


def site_stream(site: str) -> int:
    """Deterministic per-site stream id (stable across processes/versions)."""
    return zlib.crc32(site.encode("utf-8")) & 0x7FFFFFFF


def fit_block(spec: DropoutSpec, dim: int) -> DropoutSpec:
    """Clamp block_size to the largest divisor of ``dim`` <= the requested one."""
    bs = min(spec.block_size, dim)
    while dim % bs:
        bs -= 1
    return spec if bs == spec.block_size else spec.with_(block_size=bs)


@dataclasses.dataclass(frozen=True)
class DropoutPlan:
    """Mapping of named application sites to DropoutSpecs (hashable, frozen)."""

    sites: Union[Tuple[Tuple[str, DropoutSpec], ...], Mapping[str, DropoutSpec]] = ()

    def __post_init__(self):
        s = self.sites
        if isinstance(s, Mapping):
            s = s.items()
        s = tuple(sorted(((str(k), v) for k, v in s),
                         key=lambda kv: kv[0]))        # canonical: == / hash
        names = [name for name, _ in s]
        if len(set(names)) != len(names):
            dup = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate dropout site(s): {dup}")
        for name, spec in s:
            if not isinstance(spec, DropoutSpec):
                raise TypeError(f"site {name!r}: expected DropoutSpec, "
                                f"got {type(spec).__name__}")
        object.__setattr__(self, "sites", s)

    # -- lookup -------------------------------------------------------------

    @property
    def mapping(self) -> dict:
        return dict(self.sites)

    def spec(self, site: str) -> DropoutSpec:
        """Resolve a (possibly hierarchical) site name to its spec."""
        d = self.mapping
        if site in d:
            return d[site]
        base = site.rsplit("/", 1)[-1]
        if base in d:
            return d[base]
        if "*" in d:
            return d["*"]
        return _INACTIVE

    @property
    def any_active(self) -> bool:
        return any(spec.active for _, spec in self.sites)

    def active_sites(self) -> Tuple[str, ...]:
        return tuple(name for name, spec in self.sites if spec.active)

    # -- construction -------------------------------------------------------

    @staticmethod
    def off() -> "DropoutPlan":
        return DropoutPlan()

    @staticmethod
    def case(name: str, rate: float, block_size: int = 1, impl: str = "xla",
             sites: Tuple[str, ...] = ("*",)) -> "DropoutPlan":
        """One of the paper's Case I-IV at every named site.

            DropoutPlan.case("case3", rate=0.5, block_size=128,
                             sites=("nr", "rh"))
        """
        spec = DropoutSpec.case(name, rate, block_size=block_size, impl=impl)
        return DropoutPlan({s: spec for s in sites})

    @staticmethod
    def parse(text: str, sites: Tuple[str, ...] = ("*",)) -> "DropoutPlan":
        """Parse a CLI override like ``case3:0.5:bs128`` or ``off``.

        Grammar: ``off`` | ``case{1..4}:<rate>[:bs<int>][:<impl>]``.
        """
        text = text.strip()
        if text in ("", "off", "none"):
            return DropoutPlan.off()
        parts = text.split(":")
        case = parts[0]
        if case not in _masks.CASES:
            raise ValueError(f"unknown dropout case {case!r}; expected one of "
                             f"{sorted(_masks.CASES)} or 'off'")
        if len(parts) < 2:
            raise ValueError(f"dropout override {text!r} is missing a rate "
                             f"(e.g. '{case}:0.5')")
        rate = float(parts[1])
        if not 0.0 <= rate < 1.0:
            raise ValueError(f"dropout rate must be in [0, 1), got {rate}")
        block_size, impl = 1, "xla"
        for tok in parts[2:]:
            if tok.startswith("bs"):
                block_size = int(tok[2:])
                if block_size < 1:
                    raise ValueError(f"block size must be >= 1, got {tok!r}")
            elif tok in ("xla", "pallas"):
                impl = tok
            else:
                raise ValueError(f"unknown dropout override token {tok!r}")
        return DropoutPlan.case(case, rate, block_size=block_size, impl=impl,
                                sites=sites)

    def replace(self, site_specs: Mapping[str, DropoutSpec]) -> "DropoutPlan":
        """New plan with the given sites added/overridden (hierarchical
        names like "enc/layer0/nr" are valid keys)."""
        d = self.mapping
        d.update(site_specs)
        return DropoutPlan(d)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-serializable round-trippable description of the plan."""
        return {"sites": {name: spec.to_dict() for name, spec in self.sites}}

    @staticmethod
    def from_dict(d: dict) -> "DropoutPlan":
        return DropoutPlan({name: DropoutSpec.from_dict(sd)
                            for name, sd in d.get("sites", {}).items()})

    # -- binding ------------------------------------------------------------

    def bind(self, key: Optional[jax.Array], step=None, *,
             deterministic: bool = False,
             shard: Optional["BatchShard"] = None) -> "DropoutCtx":
        """Bind the plan to a PRNG key for one training step.

        ``key=None`` or ``deterministic=True`` yields an eval-mode ctx whose
        states/applies are all no-ops (the explicit replacement for the old
        implicit ``drop_key is None`` convention).

        ``shard`` marks the ctx as one batch shard of a data-parallel step
        (see the module docstring): RANDOM-pattern dense masks are sampled
        at the global batch size and row-sliced to this shard, so sharded
        and single-device runs draw identical per-row masks.
        """
        if key is None or deterministic or not self.any_active:
            return DropoutCtx(plan=self, key=None)
        if step is not None:
            key = jax.random.fold_in(key, step)
        return DropoutCtx(plan=self, key=key, shard=shard)


@dataclasses.dataclass
class MaskSchedule:
    """All ``steps`` time steps' masks for one site, sampled pre-scan.

    Phase A of the scheduled engine: the whole schedule is materialized in
    one vmapped sampling pass, so the ``lax.scan`` body never touches the
    PRNG. Structured specs store a ``(rows, nk)`` keep-block id table;
    random specs store a ``(rows, *batch, dim)`` dense mask. ``rows`` is
    ``steps`` for PER_STEP specs and 1 for FIXED ones (one mask reused at
    every step — ``rows()`` broadcasts it).

    ``steps`` is always the *padded* batch width. Under ragged batches a
    row whose sequence ends at ``lengths[b] < steps`` still consumes the
    same schedule rows ``0..steps-1`` as its unpacked counterpart — the
    kernels' carry freeze discards the masked work at frozen steps rather
    than re-indexing the schedule, which is what keeps packed and
    unpacked runs bit-equivalent under active PER_STEP dropout
    (structured masks are batch-independent; see docs/engines.md).
    """

    spec: DropoutSpec                          # block-size fitted
    steps: int
    keep_blocks: Optional[jax.Array] = None    # structured: (rows, nk) int32
    dense_mask: Optional[jax.Array] = None     # random: (rows, *batch, dim)
    scale: float = 1.0

    @property
    def inactive(self) -> bool:
        return self.keep_blocks is None and self.dense_mask is None

    @property
    def structured(self) -> bool:
        return self.keep_blocks is not None

    @property
    def fixed(self) -> bool:
        return self.spec.time_pattern == TimePattern.FIXED

    def rows(self) -> Optional[jax.Array]:
        """Per-step mask rows, leading axis ``steps`` — thread as scan xs.

        FIXED schedules hold one physical row; the broadcast here is a view
        under jit (XLA fuses it), so no T-fold copy is materialized.
        """
        table = self.keep_blocks if self.structured else self.dense_mask
        if table is None:
            return None
        if table.shape[0] == self.steps:
            return table
        return jnp.broadcast_to(table, (self.steps, *table.shape[1:]))

    def scan_rows(self) -> Optional[jax.Array]:
        """Rows a scan body actually needs as xs: the (T, ...) table of a
        PER_STEP schedule. FIXED and inactive schedules return None — their
        one mask should be closed over as a scan constant (``state(0)``),
        not sliced per step."""
        table = self.keep_blocks if self.structured else self.dense_mask
        if table is None or table.shape[0] == 1:
            return None
        return table

    def state_for_row(self, row: Optional[jax.Array]) -> DropoutState:
        """DropoutState for one scan step, built from a ``rows()`` slice
        (no PRNG — the only mask source inside a scheduled scan body)."""
        if self.inactive or row is None:
            return DropoutState(spec=self.spec)
        if self.structured:
            return DropoutState(spec=self.spec, keep_blocks=row,
                                scale=self.scale)
        return DropoutState(spec=self.spec, dense_mask=row, scale=self.scale)

    def state(self, t) -> DropoutState:
        """DropoutState at step ``t`` (index-based access, non-scan users)."""
        if self.inactive:
            return DropoutState(spec=self.spec)
        table = self.keep_blocks if self.structured else self.dense_mask
        row = table[0] if table.shape[0] == 1 else table[t]
        return self.state_for_row(row)


@dataclasses.dataclass(frozen=True)
class DropoutCtx:
    """A plan bound to (key, step): the only source of dropout randomness.

    ``shard`` (optional) marks the ctx as one batch shard of a
    data-parallel ``shard_map`` step: structured masks are batch-
    independent and replicate untouched; dense masks are sampled globally
    and sliced to this shard's rows (``_shard_rows``).
    """

    plan: DropoutPlan
    key: Optional[jax.Array] = None
    shard: Optional[BatchShard] = None

    @property
    def deterministic(self) -> bool:
        return self.key is None

    @property
    def _sharded(self) -> bool:
        return self.shard is not None and self.shard.count > 1

    def _shard_rows(self, mask: jax.Array, n_local: int,
                    axis: int) -> jax.Array:
        """This shard's ``n_local`` contiguous rows of a globally sampled
        dense mask (rows = flattened leading batch dims along ``axis``)."""
        return jax.lax.dynamic_slice_in_dim(
            mask, self.shard.index * n_local, n_local, axis)

    def spec(self, site: str) -> DropoutSpec:
        return self.plan.spec(site)

    def site_key(self, site: str, *, t=None) -> jax.Array:
        """The site's PRNG key; ``t`` indexes the site's recurrence axis."""
        if self.key is None:
            raise ValueError("site_key on a deterministic DropoutCtx")
        k = jax.random.fold_in(self.key, site_stream(site))
        if t is not None and self.spec(site).time_pattern == TimePattern.PER_STEP:
            k = jax.random.fold_in(k, t)
        return k

    def state(self, site: str, batch, dim: int, *, t=None) -> DropoutState:
        """Materialize the site's DropoutState for one application.

        ``batch`` is an int or a tuple of leading dims (random-pattern dense
        masks are shaped accordingly; structured masks ignore it).
        """
        spec = self.spec(site)
        if self.key is None or not spec.active:
            return DropoutState(spec=spec)
        spec = fit_block(spec, dim)
        shape = (batch,) if isinstance(batch, int) else tuple(batch)
        n = 1
        for s in shape:
            n *= int(s)
        # dense masks under batch sharding: sample the GLOBAL mask (same
        # key + shape as the unsharded run -> bit-identical), keep our rows
        n_sample = n * self.shard.count if self._sharded else n
        st = sdrop.make_state(self.site_key(site, t=t), spec, n_sample, dim)
        if st.dense_mask is not None and self._sharded:
            st.dense_mask = self._shard_rows(st.dense_mask, n, 0)
        if st.dense_mask is not None and len(shape) > 1:
            st.dense_mask = st.dense_mask.reshape(*shape, dim)
        return st

    def schedule(self, site: str, steps: int, batch, dim: int, *,
                 t0=0) -> MaskSchedule:
        """Sample the site's masks for ``steps`` consecutive time steps.

        The per-row key derivation is identical to ``state(site, ..., t)``:
        row ``t`` folds ``t0 + t`` into the site key for PER_STEP specs,
        FIXED specs sample a single row from the bare site key. ``t0``
        offsets the time axis (e.g. a chunk resuming mid-sequence, or an
        xlstm group continuing at ``step0``) and may be traced. ``steps``
        is the padded width — per-row sequence lengths do not shorten the
        schedule (see the MaskSchedule docstring for the ragged contract).
        """
        spec = self.spec(site)
        if self.key is None or not spec.active:
            return MaskSchedule(spec=spec, steps=steps)
        spec = fit_block(spec, dim)
        base = jax.random.fold_in(self.key, site_stream(site))
        if spec.time_pattern == TimePattern.FIXED:
            keys = base[None]
        else:
            keys = jax.vmap(lambda t: jax.random.fold_in(base, t))(
                t0 + jnp.arange(steps))
        if spec.batch_pattern == _masks.BatchPattern.STRUCTURED:
            kb = jax.vmap(lambda k: _masks.sample_keep_blocks(
                k, dim, spec.rate, spec.block_size))(keys)
            return MaskSchedule(
                spec=spec, steps=steps, keep_blocks=kb,
                scale=_masks.inverted_scale(spec.rate, dim, spec.block_size))
        shape = (batch,) if isinstance(batch, int) else tuple(batch)
        n = 1
        for s in shape:
            n *= int(s)
        # dense schedules under batch sharding: sample the GLOBAL (T, n_total,
        # dim) mask — bit-identical to the single-device run — then keep the
        # contiguous row block owned by this shard (dropout_plan module
        # docstring, "Batch sharding").
        n_sample = n * self.shard.count if self._sharded else n
        dm = jax.vmap(lambda k: _masks.random_mask(k, n_sample, dim, spec.rate))(keys)
        if self._sharded:
            dm = self._shard_rows(dm, n, 1)
        dm = dm.reshape(dm.shape[0], *shape, dim)
        return MaskSchedule(spec=spec, steps=steps, dense_mask=dm,
                            scale=1.0 / (1.0 - spec.rate))

    def apply(self, site: str, x: jax.Array, *, t=None) -> jax.Array:
        """Mask-multiply ``x`` at the site (for elementwise consumers)."""
        spec = self.spec(site)
        if self.key is None or not spec.active:
            return x
        st = self.state(site, tuple(x.shape[:-1]), x.shape[-1], t=t)
        return st.apply(x)


NULL_CTX = DropoutCtx(plan=DropoutPlan())
