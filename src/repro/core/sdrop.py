"""User-facing structured-dropout API (the paper's plug-in replacement).

A ``DropoutSpec`` selects one of the four cases of the paper's taxonomy plus
the TPU block granularity. ``DropoutState`` is what a model threads through
its layers: for structured cases it carries kept-block ids (compute is
reclaimed via sparse_matmul); for random cases it carries a dense mask
(baseline — regularization only, no speedup), matching Zaremba'14 / Gal'16.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import masks
from repro.core.masks import BatchPattern, TimePattern


@dataclasses.dataclass(frozen=True)
class DropoutSpec:
    rate: float = 0.0
    batch_pattern: BatchPattern = BatchPattern.STRUCTURED
    time_pattern: TimePattern = TimePattern.PER_STEP
    block_size: int = 1
    impl: str = "xla"                  # "xla" | "pallas"

    @property
    def structured(self) -> bool:
        return self.batch_pattern == BatchPattern.STRUCTURED and self.rate > 0.0

    @property
    def active(self) -> bool:
        return self.rate > 0.0

    def with_(self, **kw) -> "DropoutSpec":
        return dataclasses.replace(self, **kw)

    @staticmethod
    def case(name: str, rate: float, block_size: int = 1, impl: str = "xla") -> "DropoutSpec":
        bp, tp = masks.CASES[name]
        return DropoutSpec(rate=rate, batch_pattern=bp, time_pattern=tp,
                           block_size=block_size, impl=impl)


@dataclasses.dataclass
class DropoutState:
    """Materialized dropout decision for one application point.

    Exactly one of (keep_blocks) / (dense_mask) is set when active.
    """
    spec: DropoutSpec
    keep_blocks: Optional[jax.Array] = None    # structured: sorted kept block ids
    dense_mask: Optional[jax.Array] = None     # random: (batch, hidden) 0/1
    scale: float = 1.0
    # Optional secondary mask over an inner (e.g. FFN) dimension —
    # used by the beyond-paper FFN-inner structured dropout.
    inner_kb: Optional[jax.Array] = None
    inner_scale: float = 1.0

    @property
    def structured(self) -> bool:
        return self.keep_blocks is not None

    @property
    def inactive(self) -> bool:
        """True when no mask was materialized (eval mode or rate=0)."""
        return self.keep_blocks is None and self.dense_mask is None

    def apply(self, x: jax.Array) -> jax.Array:
        """Mask-multiply (no compute reclamation) — for elementwise consumers."""
        if not self.spec.active or self.inactive:
            return x
        if self.structured:
            m = masks.keep_blocks_to_mask(self.keep_blocks, x.shape[-1],
                                          self.spec.block_size)
            return x * m.astype(x.dtype) * jnp.asarray(self.scale, x.dtype)
        return x * self.dense_mask.astype(x.dtype) * jnp.asarray(self.scale, x.dtype)


def make_state(key: Optional[jax.Array], spec: DropoutSpec, batch: int,
               hidden: int, *, deterministic: bool = False) -> DropoutState:
    """Sample a DropoutState for one application (one time step / layer).

    Case-III/IV time behaviour is realized by how the *caller* derives ``key``:
    PER_STEP callers fold the step index into the key (see ``step_key``);
    FIXED callers reuse the same key each step, which with our counter-based
    sampling reproduces the identical mask.
    """
    if deterministic or not spec.active or key is None:
        return DropoutState(spec=spec)
    if spec.batch_pattern == BatchPattern.STRUCTURED:
        kb = masks.sample_keep_blocks(key, hidden, spec.rate, spec.block_size)
        scale = masks.inverted_scale(spec.rate, hidden, spec.block_size)
        return DropoutState(spec=spec, keep_blocks=kb, scale=scale)
    dm = masks.random_mask(key, batch, hidden, spec.rate)
    return DropoutState(spec=spec, dense_mask=dm, scale=1.0 / (1.0 - spec.rate))


def step_key(key: jax.Array, spec: DropoutSpec, t) -> jax.Array:
    """Derive the time-step-t key per the spec's time pattern."""
    if spec.time_pattern == TimePattern.FIXED:
        return key
    return jax.random.fold_in(key, t)
