"""Structured-dropout primitives (the paper's plug-in replacement).

A ``DropoutSpec`` selects one of the four cases of the paper's taxonomy plus
the TPU block granularity. ``DropoutState`` is the materialized decision for
one application: structured cases carry kept-block ids (compute is reclaimed
via sparse_matmul); random cases carry a dense mask (baseline —
regularization only, no speedup), matching Zaremba'14 / Gal'16.

Models do NOT call ``make_state`` directly anymore: they hold a
``repro.core.dropout_plan.DropoutPlan`` mapping named application sites to
specs, bind it once per training step (``plan.bind(key, step)``) and draw
masks from the resulting ``DropoutCtx``. The ctx owns every PRNG stream
(site-name hashing, FIXED vs PER_STEP time behaviour) — see
``dropout_plan.py`` for the full contract.

Two consumption styles, three engines (core/lstm.py)
----------------------------------------------------

``ctx.state(site, batch, dim, t=t)`` materializes ONE step's mask — the
*stepwise* engine draws these inside the ``lax.scan`` body (the reference
path). ``ctx.schedule(site, T, batch, dim)`` samples ALL steps at once into
a ``MaskSchedule`` — the *scheduled* engine (default) is two-phase:

  Phase A (pre-scan):  every site's schedule is sampled in one vmapped
      pass, and the non-recurrent (x@W) gate matmuls of every layer run
      time-batched through ``sdrop_matmul_scheduled`` — one big matmul
      instead of T scan-serialized small ones.
  Phase B (in-scan):   the scan body shrinks to the recurrent (h@U) matmul
      + the pointwise cell update; precomputed gate slices and schedule
      rows arrive as scan xs. No PRNG calls, no NR matmul in the body.

The *fused* engine shares Phase A and replaces the Phase-B scan with one
``kernels/lstm_scan`` call per layer: the whole T-step recurrence in a
single fused pass (U resident across steps, compact per-step RH gathers
off the schedule ids table, pointwise + reverse-time backward fused).

Row ``t`` of a schedule is bit-identical to ``ctx.state(..., t=t)``, so the
engines compute the same function (tests/test_engine.py asserts it for
Case I-IV on all three engines, op-by-op exactly for scheduled/stepwise).

Choosing a dropout case (the paper's Fig. 1 taxonomy)
-----------------------------------------------------

Two axes — within-batch pattern x across-time pattern — give four cases:

  ========  ===========  =========  ===========================================
  case      batch        time       use it when
  ========  ===========  =========  ===========================================
  case1     RANDOM       PER_STEP   Zaremba'14 baseline; best-known
                                    regularization, zero compute reclaim.
  case2     RANDOM       FIXED      Gal'16 variational / AWD-LSTM; one mask per
                                    sequence (RNNs) or shared across layers
                                    (depth-scanned archs).
  case3     STRUCTURED   PER_STEP   **the paper** — whole units dropped
                                    batch-uniformly, re-sampled each step:
                                    compacted (1-p)-sized matmuls in FP/BP/WG
                                    with Case-I-level task metrics.
  case4     STRUCTURED   FIXED      most restricted; static column pruning for
                                    the duration of one bind (ablation).
  ========  ===========  =========  ===========================================

"Time" is the architecture's recurrence axis: the sequence dimension for LSTM
/ sLSTM cells, the layer dimension for depth-scanned stacks (transformer,
mLSTM, SSM). The training step always re-samples (folded at bind time).
``block_size`` trades mask granularity for TPU-lane-aligned compaction:
1 = paper-faithful columns, 128 = MXU/lane-aligned blocks.

Ragged batches: STRUCTURED masks drop the same units for every row, so
they are independent of how sequences are packed into the batch —
token-packed batches (data/pipeline.py PackedBatcher) reproduce the
per-sequence losses and gradients exactly under active case3/case4
dropout with the same drop_key (tests/test_ragged.py). RANDOM masks are
per-row and tie a mask stream to a batch layout; prefer the structured
cases when mixing dropout with packed ragged traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import masks
from repro.core.masks import BatchPattern, TimePattern


@dataclasses.dataclass(frozen=True)
class DropoutSpec:
    rate: float = 0.0
    batch_pattern: BatchPattern = BatchPattern.STRUCTURED
    time_pattern: TimePattern = TimePattern.PER_STEP
    block_size: int = 1
    impl: str = "xla"                  # "xla" | "pallas"

    @property
    def structured(self) -> bool:
        return self.batch_pattern == BatchPattern.STRUCTURED and self.rate > 0.0

    @property
    def active(self) -> bool:
        return self.rate > 0.0

    def with_(self, **kw) -> "DropoutSpec":
        return dataclasses.replace(self, **kw)

    @staticmethod
    def case(name: str, rate: float, block_size: int = 1, impl: str = "xla") -> "DropoutSpec":
        bp, tp = masks.CASES[name]
        return DropoutSpec(rate=rate, batch_pattern=bp, time_pattern=tp,
                           block_size=block_size, impl=impl)

    @property
    def case_name(self) -> str:
        """The Fig.-1 case this spec realizes ("case1".."case4")."""
        pair = (self.batch_pattern, self.time_pattern)
        return next(n for n, p in masks.CASES.items() if p == pair)

    def to_dict(self) -> dict:
        return {"rate": self.rate, "batch_pattern": self.batch_pattern.value,
                "time_pattern": self.time_pattern.value,
                "block_size": self.block_size, "impl": self.impl}

    @staticmethod
    def from_dict(d: dict) -> "DropoutSpec":
        return DropoutSpec(rate=float(d["rate"]),
                           batch_pattern=BatchPattern(d["batch_pattern"]),
                           time_pattern=TimePattern(d["time_pattern"]),
                           block_size=int(d.get("block_size", 1)),
                           impl=d.get("impl", "xla"))


@dataclasses.dataclass
class DropoutState:
    """Materialized dropout decision for one application point.

    Exactly one of (keep_blocks) / (dense_mask) is set when active.
    """
    spec: DropoutSpec
    keep_blocks: Optional[jax.Array] = None    # structured: sorted kept block ids
    dense_mask: Optional[jax.Array] = None     # random: (batch, hidden) 0/1
    scale: float = 1.0
    # Optional secondary mask over an inner (e.g. FFN) dimension —
    # used by the beyond-paper FFN-inner structured dropout.
    inner_kb: Optional[jax.Array] = None
    inner_scale: float = 1.0
    inner_spec: Optional[DropoutSpec] = None

    @property
    def structured(self) -> bool:
        return self.keep_blocks is not None

    @property
    def inactive(self) -> bool:
        """True when no mask was materialized (eval mode or rate=0)."""
        return self.keep_blocks is None and self.dense_mask is None

    def apply(self, x: jax.Array) -> jax.Array:
        """Mask-multiply (no compute reclamation) — for elementwise consumers."""
        if not self.spec.active or self.inactive:
            return x
        if self.structured:
            m = masks.keep_blocks_to_mask(self.keep_blocks, x.shape[-1],
                                          self.spec.block_size)
            return x * m.astype(x.dtype) * jnp.asarray(self.scale, x.dtype)
        return x * self.dense_mask.astype(x.dtype) * jnp.asarray(self.scale, x.dtype)


def make_state(key: Optional[jax.Array], spec: DropoutSpec, batch: int,
               hidden: int, *, deterministic: bool = False) -> DropoutState:
    """Sample a DropoutState for one application (one time step / layer).

    Time behaviour is realized by how the key is derived: ``DropoutCtx``
    folds the recurrence index in for PER_STEP specs and reuses the site key
    for FIXED ones, which with counter-based sampling reproduces the
    identical mask. Models should draw states via ``DropoutCtx.state``
    rather than calling this directly.
    """
    if deterministic or not spec.active or key is None:
        return DropoutState(spec=spec)
    if spec.batch_pattern == BatchPattern.STRUCTURED:
        kb = masks.sample_keep_blocks(key, hidden, spec.rate, spec.block_size)
        scale = masks.inverted_scale(spec.rate, hidden, spec.block_size)
        return DropoutState(spec=spec, keep_blocks=kb, scale=scale)
    dm = masks.random_mask(key, batch, hidden, spec.rate)
    return DropoutState(spec=spec, dense_mask=dm, scale=1.0 / (1.0 - spec.rate))
