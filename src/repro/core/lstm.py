"""LSTM cell and stack with the paper's structured dropout (NR and RH).

The cell follows Eqs. (1)-(6): fused gate matmuls ``x@W + h@U + b`` with
W:(D,4H), U:(H,4H), gate order (i, f, g, o), then
``c' = sigmoid(f)*c + sigmoid(i)*tanh(g)``, ``h' = sigmoid(o)*tanh(c')``.

Dropout application points (Case-III: structured in batch, re-sampled per
time step):
  * NR — the non-recurrent input x_t entering W  (Zaremba'14 placement);
  * RH — the recurrent hidden h_{t-1} entering U (the paper's extension).
The cell state c is never dropped (paper §3.2). Both matmuls are
``sdrop_matmul`` calls, so FP/BP/WG all run compacted.

Time iteration is ``jax.lax.scan`` (compact HLO, O(1) program size in T).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core.dropout_plan import NULL_CTX, DropoutCtx


class LSTMState(NamedTuple):
    h: jax.Array   # (num_layers, B, H)
    c: jax.Array   # (num_layers, B, H)


def init_lstm_params(key, in_dim: int, hidden: int, num_layers: int,
                     *, init_scale: float = 0.05, dtype=jnp.float32):
    """Per-layer {W, U, b}; layer 0 consumes in_dim, the rest consume hidden."""
    params = []
    for l in range(num_layers):
        k1, k2, key = jax.random.split(key, 3)
        d = in_dim if l == 0 else hidden
        params.append({
            "W": L.uniform_init(k1, (d, 4 * hidden), init_scale, dtype),
            "U": L.uniform_init(k2, (hidden, 4 * hidden), init_scale, dtype),
            "b": jnp.zeros((4 * hidden,), dtype),
        })
    return params


def zero_state(num_layers: int, batch: int, hidden: int, dtype=jnp.float32) -> LSTMState:
    z = jnp.zeros((num_layers, batch, hidden), dtype)
    return LSTMState(h=z, c=z)


def lstm_pointwise(gates: jax.Array, c_prev: jax.Array, *,
                   forget_bias: float = 0.0, impl: str = "xla"):
    """Gate nonlinearities + state update. Pallas-fusable hot spot."""
    if impl == "pallas":
        from repro.kernels import ops as _kops
        return _kops.lstm_pointwise(gates, c_prev, forget_bias=forget_bias)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + forget_bias) * c_prev + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def lstm_cell(params, x, h_prev, c_prev, nr_drop, rh_drop, *,
              forget_bias: float = 0.0, pointwise_impl: str = "xla"):
    """One LSTM step. nr_drop / rh_drop are DropoutStates (or None)."""
    gx = L.dense_sdrop({"w": params["W"]}, x, nr_drop)
    gh = L.dense_sdrop({"w": params["U"]}, h_prev, rh_drop)
    gates = gx + gh + params["b"]
    return lstm_pointwise(gates, c_prev, forget_bias=forget_bias,
                          impl=pointwise_impl)


def lstm_stack(params, x_seq: jax.Array, state: LSTMState, *,
               ctx: Optional[DropoutCtx] = None,
               site: str = "lstm",
               forget_bias: float = 0.0,
               pointwise_impl: str = "xla"):
    """Run a multi-layer LSTM over a (T, B, D) sequence.

    Returns (outputs (T, B, H), final LSTMState). Dropout comes from the
    bound ``ctx``: layer ``l`` consumes sites ``{site}/layer{l}/nr`` and
    ``{site}/layer{l}/rh`` (resolved against the plan's "nr" / "rh" entries),
    with the sequence index ``t`` as the time axis — PER_STEP specs re-sample
    per step (Case-I/III), FIXED specs reuse one mask (Case-II/IV).
    """
    num_layers = len(params)
    hidden = state.h.shape[-1]
    batch = x_seq.shape[1]
    ctx = NULL_CTX if ctx is None else ctx

    def step(carry, xt_t):
        hs, cs = carry
        xt, t = xt_t
        new_h, new_c = [], []
        inp = xt
        for l in range(num_layers):
            nr = ctx.state(f"{site}/layer{l}/nr", batch, inp.shape[-1], t=t)
            rh = ctx.state(f"{site}/layer{l}/rh", batch, hidden, t=t)
            h, c = lstm_cell(params[l], inp, hs[l], cs[l], nr, rh,
                             forget_bias=forget_bias,
                             pointwise_impl=pointwise_impl)
            new_h.append(h)
            new_c.append(c)
            inp = h
        return (jnp.stack(new_h), jnp.stack(new_c)), inp

    T = x_seq.shape[0]
    (h_fin, c_fin), ys = jax.lax.scan(
        step, (state.h, state.c), (x_seq, jnp.arange(T)))
    return ys, LSTMState(h=h_fin, c=c_fin)
