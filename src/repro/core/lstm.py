"""LSTM cell and stack with the paper's structured dropout (NR and RH).

The cell follows Eqs. (1)-(6): fused gate matmuls ``x@W + h@U + b`` with
W:(D,4H), U:(H,4H), gate order (i, f, g, o), then
``c' = sigmoid(f)*c + sigmoid(i)*tanh(g)``, ``h' = sigmoid(o)*tanh(c')``.

Dropout application points (Case-III: structured in batch, re-sampled per
time step):
  * NR — the non-recurrent input x_t entering W  (Zaremba'14 placement);
  * RH — the recurrent hidden h_{t-1} entering U (the paper's extension).
The cell state c is never dropped (paper §3.2). Both matmuls are
``sdrop_matmul`` calls, so FP/BP/WG all run compacted.

Three execution engines share the same numerics (tests assert equivalence):

  * ``engine="scheduled"`` (default) — the two-phase engine. Phase A
    (pre-scan): every site's masks for all T steps are sampled at once into
    ``MaskSchedule``s and each layer's NR gate matmul runs time-batched —
    one (T·B, D)@(D, 4H) compacted matmul instead of T scan-serialized
    (B, D) ones. Phase B (in-scan): the per-layer ``lax.scan`` body shrinks
    to the RH matmul + ``lstm_pointwise``, consuming precomputed gate
    slices and schedule rows threaded through as scan xs — no PRNG calls
    and no NR matmul inside the scan. Layers run as successive scans
    (cuDNN-style), which is exactly the same recurrence unrolled in a
    different order.
  * ``engine="fused"`` — same Phase A, but Phase B runs as ONE fused pass
    per layer (``kernels/lstm_scan.py``): the whole T-step recurrence in a
    single kernel with U resident across steps, per-step RH keep-block
    gathers driven by the scalar-prefetched schedule ids table, and the
    pointwise cell update fused in; a custom_vjp reverse-time kernel makes
    the backward equally fused. The Pallas kernel is the TPU path; off-TPU
    the same two-pass structure runs as an XLA masked-dense scan (the
    Pallas path still validates via interpret mode, just not fast).
  * ``engine="stepwise"`` — the reference path: one scan over time with a
    Python layer loop inside, masks drawn per step via ``ctx.state``.

Time iteration is ``jax.lax.scan`` (compact HLO, O(1) program size in T);
the fused engine replaces the Phase-B scan with the persistent kernel.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core.dropout_plan import NULL_CTX, DropoutCtx

ENGINES = ("scheduled", "stepwise", "fused")


class LSTMState(NamedTuple):
    h: jax.Array   # (num_layers, B, H)
    c: jax.Array   # (num_layers, B, H)


def init_lstm_params(key, in_dim: int, hidden: int, num_layers: int,
                     *, init_scale: float = 0.05, dtype=jnp.float32):
    """Per-layer {W, U, b}; layer 0 consumes in_dim, the rest consume hidden."""
    params = []
    for l in range(num_layers):
        k1, k2, key = jax.random.split(key, 3)
        d = in_dim if l == 0 else hidden
        params.append({
            "W": L.uniform_init(k1, (d, 4 * hidden), init_scale, dtype),
            "U": L.uniform_init(k2, (hidden, 4 * hidden), init_scale, dtype),
            "b": jnp.zeros((4 * hidden,), dtype),
        })
    return params


def zero_state(num_layers: int, batch: int, hidden: int, dtype=jnp.float32) -> LSTMState:
    z = jnp.zeros((num_layers, batch, hidden), dtype)
    return LSTMState(h=z, c=z)


def lstm_pointwise(gates: jax.Array, c_prev: jax.Array, *,
                   forget_bias: float = 0.0, impl: str = "xla"):
    """Gate nonlinearities + state update. Pallas-fusable hot spot."""
    if impl == "pallas":
        from repro.kernels import ops as _kops
        return _kops.lstm_pointwise(gates, c_prev, forget_bias=forget_bias)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + forget_bias) * c_prev + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def lstm_cell(params, x, h_prev, c_prev, nr_drop, rh_drop, *,
              forget_bias: float = 0.0, pointwise_impl: str = "xla"):
    """One LSTM step. nr_drop / rh_drop are DropoutStates (or None)."""
    gx = L.dense_sdrop({"w": params["W"]}, x, nr_drop)
    gh = L.dense_sdrop({"w": params["U"]}, h_prev, rh_drop)
    gates = gx + gh + params["b"]
    return lstm_pointwise(gates, c_prev, forget_bias=forget_bias,
                          impl=pointwise_impl)


def _lstm_stack_stepwise(params, x_seq, state, *, ctx, site, forget_bias,
                         pointwise_impl, lengths=None):
    """Reference engine: one scan over time, per-step mask sampling."""
    num_layers = len(params)
    hidden = state.h.shape[-1]
    batch = x_seq.shape[1]

    def step(carry, xt_t):
        hs, cs = carry
        xt, t = xt_t
        new_h, new_c = [], []
        inp = xt
        for l in range(num_layers):
            nr = ctx.state(f"{site}/layer{l}/nr", batch, inp.shape[-1], t=t)
            rh = ctx.state(f"{site}/layer{l}/rh", batch, hidden, t=t)
            h, c = lstm_cell(params[l], inp, hs[l], cs[l], nr, rh,
                             forget_bias=forget_bias,
                             pointwise_impl=pointwise_impl)
            if lengths is not None:
                act = (t < lengths)[:, None]
                h = jnp.where(act, h, hs[l])
                c = jnp.where(act, c, cs[l])
            new_h.append(h)
            new_c.append(c)
            inp = h
        return (jnp.stack(new_h), jnp.stack(new_c)), inp

    T = x_seq.shape[0]
    (h_fin, c_fin), ys = jax.lax.scan(
        step, (state.h, state.c), (x_seq, jnp.arange(T)))
    return ys, LSTMState(h=h_fin, c=c_fin)


def _lstm_stack_scheduled(params, x_seq, state, *, ctx, site, forget_bias,
                          pointwise_impl, lengths=None):
    """Two-phase engine: NR matmuls + mask sampling hoisted out of the scan.

    Layers run as successive per-layer scans: layer l's full output sequence
    (its T hidden states) is the time-batched NR input of layer l+1, so
    every layer's x@W runs as one compacted matmul over all steps. The scan
    body is RH matmul + pointwise only; its mask rows arrive as scan xs.
    """
    num_layers = len(params)
    T, batch, _ = x_seq.shape
    hidden = state.h.shape[-1]

    inp = x_seq
    h_fin, c_fin = [], []
    for l in range(num_layers):
        nr_sched = ctx.schedule(f"{site}/layer{l}/nr", T, batch,
                                inp.shape[-1])
        rh_sched = ctx.schedule(f"{site}/layer{l}/rh", T, batch, hidden)
        # Phase A: time-batched NR gate matmul (no sequential dependence).
        gx = L.dense_sdrop_scheduled({"w": params[l]["W"]}, inp, nr_sched)
        U, b = params[l]["U"], params[l]["b"]
        # PER_STEP masks ride through the scan as xs; FIXED/inactive ones
        # are a single state closed over as a scan constant.
        rh_xs = rh_sched.scan_rows()
        rh_const = rh_sched.state(0) if rh_xs is None else None
        ts = jnp.arange(T) if lengths is not None else None

        def step(carry, xs, _U=U, _b=b, _rh=rh_sched, _const=rh_const):
            h_prev, c_prev = carry
            gx_t, rh_row, t = xs
            st = _const if rh_row is None else _rh.state_for_row(rh_row)
            gh = L.dense_sdrop({"w": _U}, h_prev, st)
            gates = gx_t + gh + _b
            h, c = lstm_pointwise(gates, c_prev, forget_bias=forget_bias,
                                  impl=pointwise_impl)
            if lengths is not None:
                act = (t < lengths)[:, None]
                h = jnp.where(act, h, h_prev)
                c = jnp.where(act, c, c_prev)
            return (h, c), h

        (h_l, c_l), ys = jax.lax.scan(
            step, (state.h[l], state.c[l]), (gx, rh_xs, ts))
        h_fin.append(h_l)
        c_fin.append(c_l)
        inp = ys
    return inp, LSTMState(h=jnp.stack(h_fin), c=jnp.stack(c_fin))


def _lstm_stack_fused(params, x_seq, state, *, ctx, site, forget_bias,
                      pointwise_impl, lengths=None):
    """Fused engine: Phase A as in "scheduled", Phase B as ONE kernel/layer.

    Each layer's whole T-step recurrence — RH matmul (compact via the
    schedule's keep-block ids) + pointwise update — runs inside a single
    ``kernels.lstm_scan`` call with U resident across steps and a fused
    reverse-time backward (custom_vjp). The gate bias is folded into the
    time-batched Phase-A matmul, so the in-pass step is exactly
    ``gx_t + rh_t`` + pointwise. The kernel impl follows the RH site's
    ``spec.impl`` ("pallas" = persistent-scan Pallas kernel, interpret mode
    off TPU; "xla" = the same fused two-pass structure as lax.scans); when
    the RH site is inactive, ``pointwise_impl`` selects it instead.
    """
    from repro.kernels import ops as _kops

    num_layers = len(params)
    T, batch, _ = x_seq.shape
    hidden = state.h.shape[-1]

    inp = x_seq
    h_fin, c_fin = [], []
    for l in range(num_layers):
        nr_sched = ctx.schedule(f"{site}/layer{l}/nr", T, batch,
                                inp.shape[-1])
        rh_sched = ctx.schedule(f"{site}/layer{l}/rh", T, batch, hidden)
        # Phase A: time-batched NR gate matmul, bias folded in.
        gx = L.dense_sdrop_scheduled(
            {"w": params[l]["W"], "b": params[l]["b"]}, inp, nr_sched)
        kw, impl = {}, pointwise_impl
        if not rh_sched.inactive:
            impl = rh_sched.spec.impl
            if rh_sched.structured:
                kw = dict(keep_blocks=rh_sched.keep_blocks,
                          block_size=rh_sched.spec.block_size,
                          scale=rh_sched.scale)
            else:
                kw = dict(dense_mask=rh_sched.dense_mask,
                          scale=rh_sched.scale)
        ys, (h_l, c_l) = _kops.lstm_scan(
            gx, params[l]["U"], state.h[l], state.c[l],
            forget_bias=forget_bias, impl=impl, lengths=lengths, **kw)
        h_fin.append(h_l)
        c_fin.append(c_l)
        inp = ys
    return inp, LSTMState(h=jnp.stack(h_fin), c=jnp.stack(c_fin))


def lstm_stack(params, x_seq: jax.Array, state: LSTMState, *,
               ctx: Optional[DropoutCtx] = None,
               site: str = "lstm",
               forget_bias: float = 0.0,
               pointwise_impl: str = "xla",
               engine: str = "scheduled",
               lengths: Optional[jax.Array] = None):
    """Run a multi-layer LSTM over a (T, B, D) sequence.

    Returns (outputs (T, B, H), final LSTMState). Dropout comes from the
    bound ``ctx``: layer ``l`` consumes sites ``{site}/layer{l}/nr`` and
    ``{site}/layer{l}/rh`` (resolved against the plan's "nr" / "rh" entries),
    with the sequence index ``t`` as the time axis — PER_STEP specs re-sample
    per step (Case-I/III), FIXED specs reuse one mask (Case-II/IV).

    ``engine`` selects the execution path (same numerics): "scheduled" =
    the two-phase engine (masks + NR matmuls hoisted out of the scan),
    "fused" = Phase B as one persistent-scan kernel per layer
    (kernels/lstm_scan.py), "stepwise" = the in-scan reference.

    ``lengths`` (B,) int32 makes the batch ragged: row b's (h, c) carries
    freeze after step ``lengths[b]`` in every layer (outputs repeat the
    last valid state, finals are the state at the last real step) and
    frozen steps contribute zero gradient — identical semantics across
    all three engines.
    """
    ctx = NULL_CTX if ctx is None else ctx
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of {ENGINES}")
    run = {"scheduled": _lstm_stack_scheduled,
           "stepwise": _lstm_stack_stepwise,
           "fused": _lstm_stack_fused}[engine]
    return run(params, x_seq, state, ctx=ctx, site=site,
               forget_bias=forget_bias, pointwise_impl=pointwise_impl,
               lengths=lengths)
