"""Parameterized layers (pure-JAX pytree params, no framework dependency).

Every layer is an ``init_*`` returning a param dict and a functional ``apply``.
``dense_sdrop`` is the workhorse: a linear layer whose input is consumed
through structured dropout (sparse_matmul.sdrop_matmul), i.e. the paper's
"plug-in replacement" for ``dropout(x) @ W``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import sparse_matmul as sm
from repro.core.sdrop import DropoutState


def uniform_init(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, minval=-scale, maxval=scale)


def normal_init(key, shape, stddev, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * stddev


def init_dense(key, in_dim, out_dim, *, bias=True, scale=None, dtype=jnp.float32):
    if scale is None:
        scale = in_dim ** -0.5
    p = {"w": uniform_init(key, (in_dim, out_dim), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(params, x):
    y = jax.lax.dot_general(x, params["w"],
                            (((x.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32).astype(x.dtype)
    if "b" in params:
        y = y + params["b"]
    return y


def dense_sdrop(params, x, drop: Optional[DropoutState], *, x_is_compact=False):
    """Linear consuming x through (structured) dropout.

    Structured state -> compacted matmul (FP/BP/WG sparsity reclaimed).
    Random state     -> mask-multiply then dense matmul (baseline).
    None/inactive    -> dense matmul.
    """
    b = params.get("b")
    if drop is None or not drop.spec.active or drop.inactive:
        y = jax.lax.dot_general(x, params["w"],
                                (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32).astype(x.dtype)
        return y + b if b is not None else y
    if drop.structured:
        return sm.sdrop_matmul(x, params["w"], drop.keep_blocks,
                               rate=drop.spec.rate,
                               block_size=drop.spec.block_size,
                               x_is_compact=x_is_compact,
                               impl=drop.spec.impl,
                               bias=b, scale=drop.scale)
    xm = drop.apply(x)
    y = jax.lax.dot_general(xm, params["w"],
                            (((x.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32).astype(x.dtype)
    return y + b if b is not None else y


def dense_sdrop_scheduled(params, x_seq, sched):
    """Time-batched linear over a (T, B, D) sequence consumed through a
    ``MaskSchedule`` (Phase A of the scheduled recurrent engine).

    Structured schedule -> one per-step-ids compacted matmul pass
    (sparse_matmul.sdrop_matmul_scheduled); FIXED schedules share a single
    compaction. Random schedule -> mask-multiply then one dense batched
    matmul. Inactive -> one dense batched matmul. In every branch the T
    steps' non-recurrent matmuls are a single XLA op, not T scan bodies.
    """
    b = params.get("b")

    def dense(x):
        y = jax.lax.dot_general(x, params["w"],
                                (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32
                                ).astype(x.dtype)
        return y + b if b is not None else y

    if sched is None or sched.inactive:
        return dense(x_seq)
    if sched.structured:
        return sm.sdrop_matmul_scheduled(x_seq, params["w"],
                                         sched.keep_blocks,
                                         rate=sched.spec.rate,
                                         block_size=sched.spec.block_size,
                                         impl=sched.spec.impl,
                                         bias=b, scale=sched.scale)
    m = sched.dense_mask
    m = jnp.broadcast_to(m, (x_seq.shape[0], *m.shape[1:]))
    xm = x_seq * m.astype(x_seq.dtype) * jnp.asarray(sched.scale, x_seq.dtype)
    return dense(xm)


def init_embedding(key, vocab, dim, *, scale=0.1, dtype=jnp.float32):
    return {"emb": uniform_init(key, (vocab, dim), scale, dtype)}


def embed(params, ids):
    return jnp.take(params["emb"], ids, axis=0)


def init_layernorm(dim, dtype=jnp.float32):
    return {"g": jnp.ones((dim,), dtype), "b": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * params["g"] + params["b"]


def init_rmsnorm(dim, dtype=jnp.float32):
    return {"g": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["g"]).astype(x.dtype)
