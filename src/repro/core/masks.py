"""Dropout mask taxonomy from the paper (Fig. 1).

Two axes:
  * within-batch: RANDOM (each sample drops its own units) vs STRUCTURED
    (every sample in the batch drops the same physical units -> column sparsity)
  * across-time: PER_STEP (new mask each time step) vs FIXED (same mask all steps)

  Case-I   = RANDOM     x PER_STEP   (Zaremba et al. 2014)
  Case-II  = RANDOM     x FIXED      (Gal & Ghahramani 2016, AWD-LSTM)
  Case-III = STRUCTURED x PER_STEP   (this paper - the technique we accelerate)
  Case-IV  = STRUCTURED x FIXED      (most restricted; supported for completeness)

Structured masks are generated as *exact-k* block subsets so that compacted
matmul shapes are static under jit: the hidden dimension H is split into
``H // block_size`` blocks and exactly ``ceil(p * nblocks)`` blocks are dropped
(sampled uniformly without replacement). ``block_size=1`` is the paper-faithful
column-granular variant; ``block_size=128`` aligns compaction with TPU lanes.

All helpers are functional and jit-friendly: they take a PRNG key and static
shape/rate arguments, and return either dense masks or kept-block index vectors.
"""
from __future__ import annotations

import enum
import functools
import jax
import jax.numpy as jnp


class BatchPattern(enum.Enum):
    RANDOM = "random"          # per-sample mask (no structured sparsity)
    STRUCTURED = "structured"  # same units dropped across the whole batch


class TimePattern(enum.Enum):
    PER_STEP = "per_step"      # re-sampled at every time step / layer application
    FIXED = "fixed"            # sampled once, reused across time steps


# The paper's four cases, as (batch, time) pairs.
CASE_I = (BatchPattern.RANDOM, TimePattern.PER_STEP)
CASE_II = (BatchPattern.RANDOM, TimePattern.FIXED)
CASE_III = (BatchPattern.STRUCTURED, TimePattern.PER_STEP)
CASE_IV = (BatchPattern.STRUCTURED, TimePattern.FIXED)

CASES = {
    "case1": CASE_I,
    "case2": CASE_II,
    "case3": CASE_III,
    "case4": CASE_IV,
}


def num_blocks(hidden: int, block_size: int) -> int:
    if hidden % block_size != 0:
        raise ValueError(f"hidden={hidden} not divisible by block_size={block_size}")
    return hidden // block_size


def num_dropped_blocks(hidden: int, rate: float, block_size: int) -> int:
    """Exactly-dropped block count. ceil so realized rate >= requested rate."""
    nb = num_blocks(hidden, block_size)
    nd = int(-(-rate * nb // 1))  # ceil
    return min(max(nd, 0), nb - 1) if rate > 0.0 else 0


def num_kept_blocks(hidden: int, rate: float, block_size: int) -> int:
    return num_blocks(hidden, block_size) - num_dropped_blocks(hidden, rate, block_size)


def kept_units(hidden: int, rate: float, block_size: int) -> int:
    return num_kept_blocks(hidden, rate, block_size) * block_size


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def sample_keep_blocks(key: jax.Array, hidden: int, rate: float, block_size: int) -> jax.Array:
    """Sample kept-block ids for a structured mask.

    Returns sorted int32 vector of length ``num_kept_blocks`` (static). Sorted
    order keeps the gather streaming-friendly (monotone HBM access) and makes
    the mask canonical for testing.
    """
    nb = num_blocks(hidden, block_size)
    nk = num_kept_blocks(hidden, rate, block_size)
    perm = jax.random.permutation(key, nb)
    return jnp.sort(perm[:nk]).astype(jnp.int32)


def keep_blocks_to_mask(keep_blocks: jax.Array, hidden: int, block_size: int) -> jax.Array:
    """Expand kept-block ids into a dense 0/1 mask of shape (hidden,)."""
    nb = num_blocks(hidden, block_size)
    blk_mask = jnp.zeros((nb,), jnp.float32).at[keep_blocks].set(1.0)
    return jnp.repeat(blk_mask, block_size)


def keep_blocks_to_unit_ids(keep_blocks: jax.Array, block_size: int) -> jax.Array:
    """Expand kept-block ids into kept-unit column indices (length k*block_size)."""
    offs = jnp.arange(block_size, dtype=jnp.int32)
    return (keep_blocks[:, None] * block_size + offs[None, :]).reshape(-1)


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def structured_mask(key: jax.Array, batch: int, hidden: int, rate: float,
                    block_size: int = 1) -> jax.Array:
    """Dense (batch, hidden) structured mask — all rows identical (Case-III/IV)."""
    m = keep_blocks_to_mask(sample_keep_blocks(key, hidden, rate, block_size),
                            hidden, block_size)
    return jnp.broadcast_to(m[None, :], (batch, hidden))


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def random_mask(key: jax.Array, batch: int, hidden: int, rate: float) -> jax.Array:
    """Dense (batch, hidden) i.i.d. Bernoulli keep-mask (Case-I/II baselines)."""
    return jax.random.bernoulli(key, 1.0 - rate, (batch, hidden)).astype(jnp.float32)


def time_keys(key: jax.Array, steps: int, time_pattern: TimePattern) -> jax.Array:
    """Per-time-step PRNG keys; FIXED repeats one key (same mask every step)."""
    if time_pattern == TimePattern.FIXED:
        return jnp.broadcast_to(key[None, :], (steps, *key.shape))
    return jax.random.split(key, steps)


def inverted_scale(rate: float, hidden: int, block_size: int = 1) -> float:
    """Inverted-dropout scale for exact-k structured masks.

    With exact-k the realized keep fraction is kept_units/hidden (may differ from
    1-rate by rounding); scale by its reciprocal so E[scaled masked x] == x.
    """
    if rate <= 0.0:
        return 1.0
    return float(hidden) / float(kept_units(hidden, rate, block_size))


def apply_mask(x: jax.Array, mask: jax.Array, rate: float, *, scale: float | None = None) -> jax.Array:
    """Inverted dropout: x * mask * 1/(keep_fraction)."""
    if scale is None:
        scale = 1.0 / (1.0 - rate) if rate > 0.0 else 1.0
    return x * mask.astype(x.dtype) * jnp.asarray(scale, x.dtype)
