"""Structured-dropout-aware matmuls (paper §3.2, Fig. 2).

The paper exploits dropout-induced *structured* sparsity in three phases:

  FP  — input  column sparsity:  y  = (x ⊙ m) @ W        → skip dropped rows of W
  BP  — output column sparsity:  δx = (δy @ Wᵀ) ⊙ m      → compute only kept cols
  WG  — input  row    sparsity:  δW = (x ⊙ m)ᵀ @ δy      → compute only kept rows

On TPU we realize all three by *compaction*: kept hidden-unit blocks are gathered
into dense MXU-aligned matmuls with static shapes (exact-k masks, see masks.py).
``custom_vjp`` wires the three phases together so a single call site —
``sdrop_matmul(x, w, keep_blocks, ...)`` — is a drop-in replacement for
``dropout(x) @ w`` whose forward *and* backward run at (1-p) FLOPs.

Two primitives cover every use in the framework:

  * ``sdrop_matmul``       (direction="in"):  dropout on the matmul *input*.
        Used for the paper's NR / RH directions (LSTM gate matmuls, transformer
        QKV / FFN-up consuming the dropped residual stream).
  * ``sdrop_matmul_out``   (direction="out"): dropout on the matmul *output*.
        Used for FFN-inner structured dropout (beyond-paper extension): the
        up-projection computes only kept columns, the down-projection consumes
        the compact activation (``x_is_compact=True``).

``impl``: "xla" (gather + dense dot, works everywhere) or "pallas"
(kernels/gather_matmul.py — fused block-gather matmul, validated in interpret
mode on CPU). Residuals are stored *compact* (B×k, not B×H) — an activation-
memory saving the paper does not claim but which falls out of the approach.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import masks as _masks


def _unit_ids(keep_blocks: jax.Array, block_size: int) -> jax.Array:
    if block_size == 1:
        return keep_blocks
    return _masks.keep_blocks_to_unit_ids(keep_blocks, block_size)


def _flatten_leading(x):
    lead = x.shape[:-1]
    return x.reshape((-1, x.shape[-1])), lead


def _matmul(a, b, out_dtype):
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_dtype)


def _float0_like(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


# ---------------------------------------------------------------------------
# direction="in": y = scale * (x ⊙ mask) @ w, via compaction.
# statics: (scale, block_size, x_is_compact, impl)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _sdrop_matmul_in(scale, block_size, x_is_compact, impl, x, w, keep_blocks):
    y, _ = _sdrop_matmul_in_fwd(scale, block_size, x_is_compact, impl, x, w, keep_blocks)
    return y


def _sdrop_matmul_in_fwd(scale, block_size, x_is_compact, impl, x, w, keep_blocks):
    ids = _unit_ids(keep_blocks, block_size)
    if x_is_compact:
        x_c = x
    else:
        x_c = jnp.take(x, ids, axis=-1)
    if impl == "pallas":
        from repro.kernels import ops as _kops
        x2, lead = _flatten_leading(x_c)
        y = _kops.gather_matmul(x2, w, keep_blocks, block_size=block_size,
                                gather="b_rows", a_is_compact=True)
        y = y.reshape((*lead, w.shape[-1]))
    else:
        w_c = jnp.take(w, ids, axis=0)
        y = _matmul(x_c, w_c, x.dtype)
    y = y * jnp.asarray(scale, y.dtype)
    # Residuals are compact: (B, k) activations — (1-p) of dense residency.
    return y, (x_c, w, keep_blocks, x.shape[-1])


def _sdrop_matmul_in_bwd(scale, block_size, x_is_compact, impl, res, dy):
    x_c, w, keep_blocks, in_dim = res
    ids = _unit_ids(keep_blocks, block_size)
    # BP (output sparsity): only the kept columns of δx are ever computed.
    if impl == "pallas":
        from repro.kernels import ops as _kops
        dy2, lead = _flatten_leading(dy)
        dx_c = _kops.gather_matmul(dy2, w, keep_blocks, block_size=block_size,
                                   gather="b_rows", a_is_compact=True,
                                   transpose_b=True)
        dx_c = dx_c.reshape((*lead, x_c.shape[-1]))
    else:
        w_c = jnp.take(w, ids, axis=0)
        dx_c = _matmul(dy, w_c.T, dy.dtype)
    dx_c = dx_c * jnp.asarray(scale, dx_c.dtype)
    if x_is_compact:
        dx = dx_c
    else:
        dx = (jnp.zeros((*dy.shape[:-1], in_dim), dx_c.dtype)
              .at[..., ids].set(dx_c))
    # WG (row sparsity): x_c is compact, so δW is a dense (k, N) matmul scattered
    # into the kept rows; dropped neurons receive no weight gradient.
    x2, _ = _flatten_leading(x_c)
    dy2, _ = _flatten_leading(dy)
    dw_c = jax.lax.dot_general(x2, dy2, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    dw_c = (dw_c * scale).astype(w.dtype)
    dw = jnp.zeros_like(w).at[ids].set(dw_c)
    return dx, dw, _float0_like(keep_blocks)


_sdrop_matmul_in.defvjp(_sdrop_matmul_in_fwd, _sdrop_matmul_in_bwd)


# ---------------------------------------------------------------------------
# scheduled (per-step ids table): the whole sequence's NR matmuls at once.
# x: (T, B, D); keep_blocks: (T, nk) — step t applies its own kept blocks.
#
# Two impls with the same semantics (y_t = scale * (x_t ⊙ m_t) @ w):
#   * "pallas" — true (1-p) compaction: the stepped gather_matmul kernel
#     resolves each step's kept blocks in the BlockSpec index_map (ids table
#     scalar-prefetched), so FP/BP run at compact FLOPs with zero-cost
#     gathers and no per-step weight copies. The TPU path.
#   * "xla"   — masked-dense batching: expand the ids table to a (T, H) 0/1
#     mask and run ONE flattened (T·B, D)@(D, N) matmul. Generic backends
#     have no fused gather-matmul: materializing w[ids_t] per step costs
#     (T, k, N) HBM (hundreds of MB at paper widths) and degrades the
#     batched matmul to T small-M gemms — measured slower than dense on
#     CPU. One big gemm is the wall-clock-optimal fallback; masked columns
#     still contribute exact zeros to δx/δW (sparsity structure preserved).
# statics: (scale, block_size, impl)
# ---------------------------------------------------------------------------


def _unit_ids_table(kb_table: jax.Array, block_size: int) -> jax.Array:
    if block_size == 1:
        return kb_table
    return jax.vmap(
        lambda kb: _masks.keep_blocks_to_unit_ids(kb, block_size))(kb_table)


def _mask_table(kb_table: jax.Array, hidden: int, block_size: int) -> jax.Array:
    """(T, nk) kept-block ids -> (T, hidden) 0/1 float mask."""
    return jax.vmap(
        lambda kb: _masks.keep_blocks_to_mask(kb, hidden, block_size)
    )(kb_table)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _sdrop_matmul_sched(scale, block_size, impl, x, w, kb_table):
    y, _ = _sdrop_matmul_sched_fwd(scale, block_size, impl, x, w, kb_table)
    return y


def _sdrop_matmul_sched_fwd(scale, block_size, impl, x, w, kb_table):
    if impl == "pallas":
        from repro.kernels import ops as _kops
        ids = _unit_ids_table(kb_table, block_size)          # (T, k)
        x_c = jnp.take_along_axis(x, ids[:, None, :], axis=2)  # (T, B, k)
        y = _kops.gather_matmul_stepped(x_c, w, kb_table,
                                        block_size=block_size,
                                        a_is_compact=True)
        y = y * jnp.asarray(scale, y.dtype)
        # Residuals compact: (B, k) per step — (1-p) of dense residency.
        return y, (x_c, w, kb_table)
    m = _mask_table(kb_table, x.shape[-1], block_size)       # (T, H)
    xm = x * m[:, None, :].astype(x.dtype) * jnp.asarray(scale, x.dtype)
    y = _matmul(xm, w, x.dtype)                              # one big gemm
    return y, (xm, w, kb_table)


def _sdrop_matmul_sched_bwd(scale, block_size, impl, res, dy):
    if impl == "pallas":
        x_c, w, kb_table = res
        ids = _unit_ids_table(kb_table, block_size)
        from repro.kernels import ops as _kops
        # BP (output sparsity): only each step's kept columns of δx.
        dx_c = _kops.gather_matmul_stepped(dy, w, kb_table,
                                           block_size=block_size,
                                           transpose_b=True)
        dx_c = dx_c * jnp.asarray(scale, dx_c.dtype)
        in_dim = w.shape[0]
        dx = jax.vmap(
            lambda ids_t, d_t: jnp.zeros((d_t.shape[0], in_dim), d_t.dtype)
            .at[:, ids_t].set(d_t))(ids, dx_c)
        # WG (row sparsity): per-step compact (k, N) products scatter-added
        # into the kept rows; blocks kept at several steps accumulate.
        dw_c = jnp.einsum("tbk,tbn->tkn", x_c, dy,
                          preferred_element_type=jnp.float32)
        dw_c = (dw_c * scale).astype(w.dtype)
        dw = jnp.zeros_like(w).at[ids].add(dw_c)
        return dx, dw, _float0_like(kb_table)
    xm, w, kb_table = res
    m = _mask_table(kb_table, w.shape[0], block_size)
    # BP: one big gemm; each step's dropped columns masked to exact zeros.
    dx = _matmul(dy, w.T, dy.dtype)
    dx = dx * m[:, None, :].astype(dx.dtype) * jnp.asarray(scale, dx.dtype)
    # WG: one big gemm; rows dropped at EVERY step receive exactly zero
    # (their xm rows are zero), matching the scatter-add result.
    x2 = xm.reshape(-1, xm.shape[-1])
    dy2 = dy.reshape(-1, dy.shape[-1])
    dw = jax.lax.dot_general(x2, dy2, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32
                             ).astype(w.dtype)
    return dx, dw, _float0_like(kb_table)


_sdrop_matmul_sched.defvjp(_sdrop_matmul_sched_fwd, _sdrop_matmul_sched_bwd)


# ---------------------------------------------------------------------------
# direction="out": y_c = scale * (x @ w)[:, kept]  (compact output).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _sdrop_matmul_out(scale, block_size, impl, x, w, keep_blocks):
    y, _ = _sdrop_matmul_out_fwd(scale, block_size, impl, x, w, keep_blocks)
    return y


def _sdrop_matmul_out_fwd(scale, block_size, impl, x, w, keep_blocks):
    ids = _unit_ids(keep_blocks, block_size)
    if impl == "pallas":
        from repro.kernels import ops as _kops
        x2, lead = _flatten_leading(x)
        y_c = _kops.gather_matmul(x2, w, keep_blocks, block_size=block_size,
                                  gather="b_cols")
        y_c = y_c.reshape((*lead, y_c.shape[-1]))
    else:
        w_c = jnp.take(w, ids, axis=1)
        y_c = _matmul(x, w_c, x.dtype)
    y_c = y_c * jnp.asarray(scale, y_c.dtype)
    return y_c, (x, w, keep_blocks)


def _sdrop_matmul_out_bwd(scale, block_size, impl, res, dy_c):
    x, w, keep_blocks = res
    ids = _unit_ids(keep_blocks, block_size)
    w_c = jnp.take(w, ids, axis=1)                      # (K, k)
    dx = _matmul(dy_c, w_c.T, x.dtype) * jnp.asarray(scale, x.dtype)
    x2, _ = _flatten_leading(x)
    dy2, _ = _flatten_leading(dy_c)
    dw_c = jax.lax.dot_general(x2, dy2, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    dw_c = (dw_c * scale).astype(w.dtype)
    dw = jnp.zeros_like(w).at[:, ids].set(dw_c)
    return dx, dw, _float0_like(keep_blocks)


_sdrop_matmul_out.defvjp(_sdrop_matmul_out_fwd, _sdrop_matmul_out_bwd)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def sdrop_matmul(x: jax.Array, w: jax.Array,
                 keep_blocks: Optional[jax.Array],
                 *,
                 rate: float,
                 block_size: int = 1,
                 x_is_compact: bool = False,
                 impl: str = "xla",
                 bias: Optional[jax.Array] = None,
                 scale: Optional[float] = None) -> jax.Array:
    """``dropout(x) @ w (+ bias)`` with structured-sparsity compute reclamation.

    ``keep_blocks`` — sorted kept-block ids from masks.sample_keep_blocks.
    ``keep_blocks=None`` or ``rate=0`` falls back to a dense matmul (eval mode).
    ``x_is_compact`` — x is already compact over kept units (e.g. FFN-down
    consuming a compact FFN-inner activation).
    """
    if keep_blocks is None or rate <= 0.0:
        y = _matmul(x, w, x.dtype)
    else:
        if scale is None:
            scale = _masks.inverted_scale(rate, w.shape[0], block_size)
        y = _sdrop_matmul_in(float(scale), int(block_size), bool(x_is_compact),
                             impl, x, w, keep_blocks)
    if bias is not None:
        y = y + bias
    return y


def sdrop_matmul_scheduled(x: jax.Array, w: jax.Array,
                           keep_blocks: Optional[jax.Array],
                           *,
                           rate: float,
                           block_size: int = 1,
                           impl: str = "xla",
                           bias: Optional[jax.Array] = None,
                           scale: Optional[float] = None) -> jax.Array:
    """Time-batched ``dropout(x_t) @ w`` for a whole mask schedule.

    x: (T, B, D); ``keep_blocks``: a (T, nk) per-step ids table (PER_STEP
    schedules) or (1, nk) (FIXED — delegates to the single-mask
    ``sdrop_matmul``, one compaction shared by all steps). All T steps'
    non-recurrent matmuls run in one pass outside the scan: FP/BP are
    per-step compact, WG scatter-adds each step's compact (k, N) product
    into the kept rows of δW.
    """
    if keep_blocks is None or rate <= 0.0:
        y = _matmul(x, w, x.dtype)
    else:
        if scale is None:
            scale = _masks.inverted_scale(rate, w.shape[0], block_size)
        if keep_blocks.ndim != 2:
            raise ValueError(f"scheduled keep_blocks must be (T, nk), got "
                             f"{keep_blocks.shape}")
        if keep_blocks.shape[0] == 1:
            return sdrop_matmul(x, w, keep_blocks[0], rate=rate,
                                block_size=block_size, impl=impl, bias=bias,
                                scale=scale)
        y = _sdrop_matmul_sched(float(scale), int(block_size), impl,
                                x, w, keep_blocks)
    if bias is not None:
        y = y + bias
    return y


def sdrop_matmul_out(x: jax.Array, w: jax.Array,
                     keep_blocks: Optional[jax.Array],
                     *,
                     rate: float,
                     block_size: int = 1,
                     impl: str = "xla",
                     bias: Optional[jax.Array] = None,
                     scale: float = 1.0) -> jax.Array:
    """Compute only the kept output columns of ``x @ w`` (compact result).

    The dropout scale is usually deferred to the consuming ``sdrop_matmul``
    (scale=1 here) so that elementwise nonlinearities between up/down
    projections see un-rescaled activations, exactly matching
    ``dropout(act(x @ w))`` semantics.
    """
    if keep_blocks is None or rate <= 0.0:
        y = _matmul(x, w, x.dtype)
        if bias is not None:
            y = y + bias
        return y
    y = _sdrop_matmul_out(float(scale), int(block_size), impl, x, w, keep_blocks)
    if bias is not None:
        ids = _unit_ids(keep_blocks, block_size)
        y = y + jnp.take(bias, ids, axis=0)
    return y


def scatter_compact(y_c: jax.Array, keep_blocks: jax.Array, full_dim: int,
                    *, block_size: int = 1) -> jax.Array:
    """Expand a compact (…, k) tensor back to (…, H) with zeros at dropped units."""
    ids = _unit_ids(keep_blocks, block_size)
    return (jnp.zeros((*y_c.shape[:-1], full_dim), y_c.dtype)
            .at[..., ids].set(y_c))


def gather_compact(x: jax.Array, keep_blocks: jax.Array, *, block_size: int = 1) -> jax.Array:
    """Gather kept units: (…, H) → (…, k)."""
    return jnp.take(x, _unit_ids(keep_blocks, block_size), axis=-1)
