"""Length-aware loss and metric helpers for ragged (token-packed) batches.

Rectangular batches pay for every padding position twice: once in FLOPs
and once in the loss denominator. These helpers make the loss side exact —
per-token NLL masked by a per-row length (or an explicit mask), averaged
over *real* tokens only — so a token-packed batch optimizes the same
objective as the per-sequence unpacked reference (tests/test_ragged.py
asserts bit-level agreement). The FLOPs side is the kernels' ``lengths``
carry-freeze (see kernels/cell_scan.py) plus data/pipeline.py's packing.

Conventions: ``lengths`` is (B,) int32 real-token counts; masks produced
here are (B, T) float32 with 1.0 on real positions. Dummy rows packed to
fill a bucket batch have length 0 and thus contribute nothing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def length_mask(lengths: jax.Array, seq_len: int) -> jax.Array:
    """(B,) lengths -> (B, T) float32 mask; 1.0 where t < lengths[b]."""
    t = jnp.arange(seq_len)
    return (t[None, :] < lengths[:, None]).astype(jnp.float32)


def masked_mean(values: jax.Array, mask: jax.Array) -> jax.Array:
    """Mean of ``values`` over positions where ``mask`` is nonzero.

    Shapes must broadcast; the denominator is clamped to 1 so an all-pad
    batch (e.g. a bucket filled with dummy rows) yields 0.0, not NaN.
    """
    m = mask.astype(jnp.float32)
    return (values.astype(jnp.float32) * m).sum() / jnp.maximum(m.sum(), 1.0)


def masked_token_nll(logits: jax.Array, labels: jax.Array,
                     mask: jax.Array) -> jax.Array:
    """Mean NLL over real tokens. logits (B, T, V), labels/mask (B, T)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return masked_mean(logz - tgt, mask)


def masked_lm_loss(head: dict, feats: jax.Array, labels: jax.Array,
                   mask: jax.Array, *, chunk: int = 1024) -> jax.Array:
    """Chunked masked softmax-xent: mean NLL over real tokens.

    ``head["w"]`` (D, V) (+ optional ``head["b"]``) applied to feats
    (B, T, D) in time-major chunks so the (tokens, V) logits never fully
    materialize — the masked twin of ``transformer.lm_loss`` (which
    divides by B*T and has no mask support).
    """
    B, T, D = feats.shape
    w = head["w"]
    b = head.get("b")
    f2 = feats.reshape(B * T, D)
    l2 = labels.reshape(B * T)
    m2 = mask.reshape(B * T).astype(jnp.float32)
    n_chunks = max(1, -(-f2.shape[0] // chunk))
    pad = n_chunks * chunk - f2.shape[0]
    f2 = jnp.pad(f2, ((0, pad), (0, 0)))
    l2 = jnp.pad(l2, (0, pad))
    m2 = jnp.pad(m2, (0, pad))

    def body(carry, xs):
        f_c, l_c, m_c = xs
        logits = f_c @ w
        if b is not None:
            logits = logits + b
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, l_c[:, None], axis=-1)[:, 0]
        return carry + ((logz - tgt) * m_c).sum(), None

    total, _ = jax.lax.scan(
        body, jnp.float32(0.0),
        (f2.reshape(n_chunks, chunk, D), l2.reshape(n_chunks, chunk),
         m2.reshape(n_chunks, chunk)))
    return total / jnp.maximum(mask.astype(jnp.float32).sum(), 1.0)


def resolve_mask(batch: dict, tokens: jax.Array,
                 key: str = "lengths") -> Optional[jax.Array]:
    """(B, T) mask from ``batch[key]`` lengths, or None if rectangular."""
    lengths = batch.get(key)
    if lengths is None:
        return None
    return length_mask(lengths, tokens.shape[1])
