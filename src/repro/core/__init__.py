"""Core: the paper's structured-dropout technique as composable JAX modules."""
from repro.core.masks import (BatchPattern, TimePattern, CASES,
                              sample_keep_blocks, structured_mask, random_mask,
                              kept_units, inverted_scale)
from repro.core.dropout_plan import DropoutCtx, DropoutPlan, NULL_CTX
from repro.core.sdrop import DropoutSpec, DropoutState, make_state
from repro.core.sparse_matmul import (sdrop_matmul, sdrop_matmul_out,
                                      gather_compact, scatter_compact)
