"""Serving driver: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b --smoke \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import adapters
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod
from repro.serving import DecodeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = configs.get_arch(args.arch)
    cfg = spec.smoke() if args.smoke else spec.full()
    mesh = mesh_mod.make_host_mesh()
    rules = shd.rules_for_mesh(mesh)
    max_seq = args.max_seq or (args.prompt_len + args.gen)

    init_fn, _, _, _ = steps_mod.param_setup(spec, cfg, mesh, rules,
                                             seed=args.seed)
    params = init_fn()
    vocab = getattr(cfg, "vocab", 256)
    rng = np.random.default_rng(args.seed)

    engine = DecodeEngine(spec=spec, cfg=cfg, params=params,
                          max_seq=max_seq, batch=args.batch, rules=rules,
                          temperature=args.temperature)

    # --- prefill (kv-cache archs consume the full prompt; recurrent archs
    # replay it token by token through the state)
    prompt = rng.integers(3, vocab, size=(args.batch, args.prompt_len))
    prompt = jnp.asarray(prompt, jnp.int32)
    t0 = time.time()
    if spec.kind == "transformer":
        batch = {"tokens": prompt}
        if getattr(cfg, "embeds_in", False):
            batch = {"embeds": jnp.asarray(rng.standard_normal(
                (args.batch, args.prompt_len, cfg.d_model)), cfg.compute_dtype)}
        if getattr(cfg, "is_encoder_decoder", False):
            from repro.models import transformer as T
            frames = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.enc_seq, cfg.d_model)) * 0.02,
                cfg.compute_dtype)
            mem = T.encode(params, frames, cfg, rules=rules)
            f = adapters.prefill_fn(spec)
            _, engine.state = f(params, batch, cfg, engine.state, rules=rules)
        else:
            engine.prefill(batch)
    else:
        for t in range(args.prompt_len):
            _, engine.state = adapters.decode_fn(spec)(
                params, cfg, engine.state, prompt[:, t:t + 1], t, rules=rules)
    t_prefill = time.time() - t0

    # --- decode (positions continue after the prefilled prompt)
    t0 = time.time()
    out = engine.generate(prompt[:, -1:], args.gen, seed=args.seed,
                          start_pos=args.prompt_len)
    t_decode = time.time() - t0
    print(f"prefill {args.prompt_len} tok: {t_prefill*1e3:.0f} ms; "
          f"decode {args.gen} tok: {t_decode*1e3:.0f} ms "
          f"({args.gen*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample continuation ids:", out[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
