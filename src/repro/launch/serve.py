"""Serving driver: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b --smoke \
        --batch 4 --prompt-len 16 --gen 32

``--loop python`` swaps the on-device chunked decode loop for the
per-token host loop (the pre-PR6 baseline) — useful for A/B'ing the
dispatch overhead. ``--trace N`` serves N synthetic ragged requests
through the continuous-batching scheduler instead of one rectangular
batch and reports sustained tokens/sec.
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod
from repro.serving import DecodeEngine, Request, prompt_prefill, serve


def _ragged_trace(n: int, vocab: int, prompt_max: int, gen_max: int,
                  seed: int):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(3, vocab,
                                        int(rng.integers(2, prompt_max + 1))),
                    max_new=int(rng.integers(max(2, gen_max // 4),
                                             gen_max + 1)))
            for i in range(n)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--eos", type=int, default=-1)
    ap.add_argument("--loop", choices=("device", "python"), default="device")
    ap.add_argument("--trace", type=int, default=0,
                    help="serve N ragged requests through the "
                         "continuous-batching scheduler instead of one "
                         "rectangular batch")
    args = ap.parse_args(argv)

    spec = configs.get_arch(args.arch)
    cfg = spec.smoke() if args.smoke else spec.full()
    mesh = mesh_mod.make_host_mesh()
    rules = shd.rules_for_mesh(mesh)
    max_seq = args.max_seq or (args.prompt_len + args.gen)

    init_fn, _, _, _ = steps_mod.param_setup(spec, cfg, mesh, rules,
                                             seed=args.seed)
    params = init_fn()
    vocab = getattr(cfg, "vocab", 256)
    rng = np.random.default_rng(args.seed)

    engine = DecodeEngine(spec=spec, cfg=cfg, params=params,
                          max_seq=max_seq, batch=args.batch, rules=rules,
                          mesh=mesh, temperature=args.temperature,
                          eos_id=args.eos, chunk=args.chunk)

    if args.trace:
        reqs = _ragged_trace(args.trace, vocab, args.prompt_len, args.gen,
                             args.seed)
        t0 = time.time()
        outs = serve(engine, reqs, chunk=args.chunk)
        dt = time.time() - t0
        total = sum(len(v) for v in outs.values())
        print(f"continuous trace: {args.trace} requests over {args.batch} "
              f"slots -> {total} tokens in {dt*1e3:.0f} ms "
              f"({total/max(dt, 1e-9):.1f} tok/s, "
              f"{engine.chunks_run} device dispatches)")
        return 0

    # --- rectangular prefill (both cache kinds go through the shared
    # serving/prefill helper; whisper-style enc-dec keeps its frame branch)
    prompt = rng.integers(3, vocab, size=(args.batch, args.prompt_len))
    prompt = jnp.asarray(prompt, jnp.int32)
    t0 = time.time()
    if spec.kind == "transformer" and (getattr(cfg, "embeds_in", False)
                                       or getattr(cfg, "is_encoder_decoder",
                                                  False)):
        # synthetic-input transformers (embeds-in / whisper enc-dec) build
        # their own prefill batch; adapters.prefill_fn runs the encoder
        batch = {"tokens": prompt[:, :-1]}
        if getattr(cfg, "embeds_in", False):
            batch = {"embeds": jnp.asarray(rng.standard_normal(
                (args.batch, args.prompt_len - 1, cfg.d_model)),
                cfg.compute_dtype)}
        if getattr(cfg, "is_encoder_decoder", False):
            batch["frames"] = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.enc_seq, cfg.d_model)) * 0.02,
                cfg.compute_dtype)
        engine.prefill(batch)
        if getattr(cfg, "embeds_in", False):
            print("prefill ok; embeds-in archs decode from embeddings, not "
                  "token ids — no token decode loop to run")
            return 0
        tok0, pos0 = prompt[:, -1:], args.prompt_len - 1
    else:
        engine.state, tok0, pos0 = prompt_prefill(
            spec, cfg, params, prompt, state=engine.state, rules=rules)
    t_prefill = time.time() - t0

    # --- decode (positions continue after the prefilled prompt)
    t0 = time.time()
    gen = (engine.generate if args.loop == "device"
           else engine.generate_python)
    out = gen(tok0, args.gen, seed=args.seed, start_pos=pos0)
    t_decode = time.time() - t0
    print(f"prefill {args.prompt_len} tok: {t_prefill*1e3:.0f} ms; "
          f"decode {args.gen} tok [{args.loop} loop]: {t_decode*1e3:.0f} ms "
          f"({args.gen*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample continuation ids:", out[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
