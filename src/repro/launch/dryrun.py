import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import (device count locks on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
(no __future__ import here: the XLA_FLAGS lines above must stay first.)

For each cell this:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. eval_shapes params/optimizer/state (no allocation — 480B params stay
     abstract),
  3. jits the real step function with NamedShardings and calls
     .lower().compile(),
  4. records memory_analysis() + cost_analysis() + parsed collective bytes
     into a JSON cache (incremental: done cells are skipped on re-run).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --mesh single --variant dense
"""

import argparse
import json
import time
import traceback

from repro import configs
from repro.configs.shapes import SHAPES
from repro.distributed import sharding as shd
from repro.launch import hlo_cost
from repro.launch import mesh as mesh_mod
from repro.launch import roofline as rf
from repro.launch import steps


def cell_id(arch, shape, mesh_name, variant):
    return f"{arch}|{shape}|{mesh_name}|{variant}"


def run_cell(spec, shape, mesh, rules, *, use_dropout, dropout="",
             engine="", collect_hlo=False):
    cfg = spec.full()
    cell = steps.build_cell(spec, cfg, shape, mesh, rules,
                            use_dropout=use_dropout, dropout=dropout,
                            engine=engine)
    t0 = time.time()
    with mesh:
        lowered = cell.jitted.lower(*cell.example_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost_raw = compiled.cost_analysis()           # loop bodies counted once
    hlo = compiled.as_text()
    la = hlo_cost.analyze_hlo(hlo)                # loop-aware re-derivation

    n_params = rf.count_params(steps.param_setup(spec, cfg, mesh, rules)[1])
    n_active = rf.active_params(spec, cfg, n_params)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    model_flops = rf.model_flops_for(shape.kind, n_active, tokens)
    chips = mesh.devices.size
    roof = rf.analyze_loop_aware(la, chips=chips, model_flops=model_flops)

    rec = {
        "arch": spec.name, "shape": shape.name, "kind": shape.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": int(chips),
        "status": "ok",
        "params": int(n_params), "active_params": int(n_active),
        "tokens_per_step": int(tokens),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": _mem_dict(mem),
        "cost_raw": {k: float(v) for k, v in (cost_raw or {}).items()
                     if isinstance(v, (int, float))},
        "cost": la.as_dict(),
        "roofline": {
            "t_compute_s": roof.t_compute, "t_memory_s": roof.t_memory,
            "t_collective_s": roof.t_collective,
            "bottleneck": roof.bottleneck,
            "model_flops": roof.model_flops,
            "flops_ratio": roof.flops_ratio,
        },
    }
    if collect_hlo:
        rec["hlo_len"] = len(hlo)
    return rec


def _mem_dict(mem):
    if mem is None:
        return {}
    out = {}
    for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            out[f] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="sdrop",
                    choices=["sdrop", "dense"],
                    help="train cells: structured dropout on (paper mode) "
                         "or off (dense baseline)")
    ap.add_argument("--dropout", default="",
                    help="dropout-plan override applied to every lowered "
                         "cell (e.g. case3:0.5:bs128)")
    ap.add_argument("--engine", default="",
                    choices=["", "scheduled", "stepwise", "fused"],
                    help="recurrent-engine override applied to every "
                         "lowered cell")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--rules", default="",
                    help="comma list of logical=mesh overrides, e.g. "
                         "expert=model,seq=model")
    args = ap.parse_args()

    archs = (list(configs.ASSIGNED_NAMES) if args.arch == "all"
             else args.arch.split(","))
    shapes = (list(SHAPES) if args.shape == "all" else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    cache = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            cache = json.load(f)

    overrides = {}
    for kv in args.rules.split(","):
        if "=" in kv:
            k, v = kv.split("=")
            overrides[k] = None if v in ("none", "None") else v

    n_ok = n_skip = n_fail = 0
    for arch_name in archs:
        spec = configs.get_arch(arch_name)
        for shape_name in shapes:
            shape = SHAPES[shape_name]
            skip = spec.applicable(shape_name)
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                cid = cell_id(arch_name, shape_name, mesh_name, args.variant)
                if skip:
                    cache[cid] = {"arch": arch_name, "shape": shape_name,
                                  "mesh": mesh_name, "status": "skip",
                                  "reason": skip}
                    n_skip += 1
                    print(f"[skip] {cid}: {skip[:60]}")
                    continue
                if cid in cache and cache[cid].get("status") == "ok":
                    n_ok += 1
                    print(f"[cached] {cid}")
                    continue
                mesh = mesh_mod.make_production_mesh(multi_pod=multi)
                rules = shd.rules_for_mesh(mesh, overrides)
                t0 = time.time()
                try:
                    rec = run_cell(spec, shape, mesh, rules,
                                   use_dropout=(args.variant == "sdrop"),
                                   dropout=args.dropout,
                                   engine=args.engine)
                    rec["variant"] = args.variant
                    cache[cid] = rec
                    n_ok += 1
                    r = rec["roofline"]
                    print(f"[ok] {cid}  compile={rec['compile_s']}s "
                          f"compute={r['t_compute_s']*1e3:.1f}ms "
                          f"mem={r['t_memory_s']*1e3:.1f}ms "
                          f"coll={r['t_collective_s']*1e3:.1f}ms "
                          f"bottleneck={r['bottleneck']} "
                          f"ratio={r['flops_ratio']:.3f}")
                except Exception as e:
                    n_fail += 1
                    cache[cid] = {"arch": arch_name, "shape": shape_name,
                                  "mesh": mesh_name, "status": "fail",
                                  "error": f"{type(e).__name__}: {e}"}
                    print(f"[FAIL] {cid} ({time.time()-t0:.0f}s): "
                          f"{type(e).__name__}: {str(e)[:200]}")
                    traceback.print_exc(limit=3)
                with open(args.out, "w") as f:
                    json.dump(cache, f, indent=1)

    with open(args.out, "w") as f:       # final dump (covers skip records)
        json.dump(cache, f, indent=1)
    print(f"\ndone: ok={n_ok} skip={n_skip} fail={n_fail} -> {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
