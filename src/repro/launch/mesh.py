"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax init; everything else sees
the real device count).

  single-pod: (16, 16)    axes ("data", "model")   = 256 chips (one v5e pod)
  multi-pod:  (2, 16, 16) axes ("pod", "data", "model") = 512 chips; "pod"
              is the DCN axis — gradient sync crosses it once per step,
              optionally int8-compressed (optim.compress).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (CPU tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_data_mesh(n: int):
    """(n, 1) ("data", "model") mesh over the first ``n`` host devices.

    The data-parallel training mesh (launch/train.py --mesh, the
    distributed tests' device sweep): batch shards over "data", params
    replicate over the size-1 "model" axis. Raises if the host has fewer
    than ``n`` devices."""
    devs = jax.devices()
    if n < 1 or n > len(devs):
        raise ValueError(f"mesh size {n} out of range for "
                         f"{len(devs)} host devices")
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:n]).reshape(n, 1), ("data", "model"))
