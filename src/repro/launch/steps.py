"""Step builders: train_step / prefill_step / serve_step with shardings.

Everything the dry-run lowers and the drivers execute is built here, so the
compiled artifact is identical in both paths. Parameters, optimizer state,
batches and decode state all get NamedShardings derived from the logical
axes + rules; train_step donates (params, opt_state), serve_step donates the
decode state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import adapters
from repro.configs.base import ArchSpec
from repro.configs.shapes import ShapeSpec
from repro.distributed import sharding as shd


def replicated(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# parameter / optimizer / batch shardings
# ---------------------------------------------------------------------------


def param_setup(spec: ArchSpec, cfg, mesh: Mesh, rules: shd.ShardingRules,
                seed: int = 0):
    """-> (init_fn() -> params, param_shapes, param_shardings, axes_tree).

    init is deferred (callable) so the dry-run can eval_shape it without
    allocating 480B parameters.
    """
    key = jax.random.PRNGKey(seed)

    def init_tagged():
        return adapters.init_params(spec.kind, key, cfg)

    tagged_shapes = jax.eval_shape(init_tagged)
    shapes, axes = shd.unzip(tagged_shapes)
    shardings = shd.make_shardings(axes, rules, mesh, shapes)

    def init_fn():
        return shd.strip(init_tagged())

    return init_fn, shapes, shardings, axes


def opt_state_shardings(opt_state_shapes, param_shardings, mesh):
    """Mirror param shardings onto optimizer-state trees (m/v/avg), scalars
    replicated. Handles our optimizers' state shapes + chain tuples."""
    rep = replicated(mesh)

    def walk(s):
        if isinstance(s, tuple):
            return tuple(walk(x) for x in s)
        if isinstance(s, dict):
            out = {}
            for k, v in s.items():
                if k in ("m", "v", "avg"):
                    out[k] = param_shardings
                else:
                    out[k] = jax.tree.map(lambda _: rep, v)
            return out
        return jax.tree.map(lambda _: rep, s)

    return walk(opt_state_shapes)


def batch_shardings(spec: ArchSpec, cfg, shape: ShapeSpec, mesh: Mesh,
                    rules: shd.ShardingRules, specs=None):
    specs = specs or adapters.train_batch_specs(spec, cfg, shape)
    axes = adapters.batch_logical_axes(spec, cfg, shape)
    return {k: NamedSharding(
        mesh, shd.logical_to_pspec(axes[k], rules, specs[k].shape, mesh))
        for k in specs}


def decode_state_shardings(spec: ArchSpec, cfg, state_shapes, mesh, rules):
    axes = adapters.decode_state_axes(spec, cfg)
    return {k: NamedSharding(
        mesh, shd.logical_to_pspec(axes[k], rules,
                                   state_shapes[k].shape, mesh))
        for k in state_shapes}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(spec: ArchSpec, cfg, opt: optim.optimizers.Optimizer,
                    rules: Optional[shd.ShardingRules], *,
                    n_micro: int = 1, use_dropout: bool = True):
    """(params, opt_state, batch, step, key) -> (params, opt_state, loss)."""
    lfn = adapters.loss_fn(spec.kind)
    grad_fn = optim.gradient_accumulation(
        lambda p, b, **kw: lfn(p, b, cfg, rules=rules, **kw), n_micro)

    def train_step(params, opt_state, batch, step, key):
        loss, grads = grad_fn(params, batch,
                              drop_key=key if use_dropout else None,
                              step=step)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


def make_sharded_loss_and_grad(spec_or_kind, cfg, mesh: Mesh, *,
                               rules: Optional[shd.ShardingRules] = None,
                               use_dropout: bool = True):
    """(params, batch, step, key) -> (loss, grads) under batch-sharded
    shard_map — loss and grads match the single-device ``loss_fn`` allclose
    (exactly, in exact arithmetic; see distributed/data_parallel.py).

    ``spec_or_kind`` is an ArchSpec or a kind string; only the recurrent
    families (``adapters.SHARD_KINDS``) have the shard-safe dropout path.
    """
    from repro.distributed import data_parallel as dp
    kind = getattr(spec_or_kind, "kind", spec_or_kind)
    if kind not in adapters.SHARD_KINDS:
        raise ValueError(f"{kind!r} has no sharded train path; "
                         f"supported: {adapters.SHARD_KINDS}")
    lfn = adapters.loss_fn(kind)
    wfn = adapters.loss_weight(kind)

    def local_loss(params, batch, step, key, shard):
        return lfn(params, batch, cfg, rules=rules,
                   drop_key=key if use_dropout else None,
                   step=step, shard=shard)

    return dp.sharded_value_and_grad(
        local_loss, lambda b: wfn(b, cfg), mesh)


def make_sharded_train_step(spec_or_kind, cfg,
                            opt: optim.optimizers.Optimizer, mesh: Mesh, *,
                            rules: Optional[shd.ShardingRules] = None,
                            use_dropout: bool = True):
    """Data-parallel twin of ``make_train_step``: same signature
    ``(params, opt_state, batch, step, key) -> (params, opt_state, loss)``,
    with loss/grads computed under shard_map on ``mesh`` (params and
    optimizer state replicated, batch sharded, grads psum'd exactly)."""
    grad_fn = make_sharded_loss_and_grad(spec_or_kind, cfg, mesh,
                                         rules=rules,
                                         use_dropout=use_dropout)

    def train_step(params, opt_state, batch, step, key):
        loss, grads = grad_fn(params, batch, step, key)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


def make_prefill_step(spec: ArchSpec, cfg, rules):
    f = adapters.prefill_fn(spec)

    def prefill_step(params, batch, state):
        feats, state = f(params, batch, cfg, state, rules=rules)
        return feats, state

    return prefill_step


def make_serve_step(spec: ArchSpec, cfg, rules):
    decode = adapters.decode_fn(spec)

    def serve_step(params, state, tokens, pos):
        logits, state = decode(params, cfg, state, tokens, pos, rules=rules)
        return logits, state

    return serve_step


# ---------------------------------------------------------------------------
# lowering bundles (shared by dryrun + drivers)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LoweredCell:
    kind: str                    # train | prefill | decode
    jitted: Any
    example_args: tuple          # ShapeDtypeStructs suitable for .lower()
    donate: tuple = ()


def default_opt(cfg) -> optim.optimizers.Optimizer:
    return optim.chain(optim.clip_by_global_norm(1.0),
                       optim.adamw(1e-4, weight_decay=0.01))


def build_cell(spec: ArchSpec, cfg, shape: ShapeSpec, mesh: Mesh,
               rules: shd.ShardingRules, *, use_dropout: bool = True,
               n_micro: int = 1, dropout: str = "",
               engine: str = "") -> LoweredCell:
    """Assemble the jitted step + abstract inputs for one (arch, shape).

    ``dropout`` is an optional CLI-style plan override ("case3:0.5:bs128")
    applied to the config before lowering, so dry-runs/perf sweeps lower the
    exact plan the trainer would run. ``engine`` likewise overrides the
    recurrent execution engine ("scheduled" | "stepwise" | "fused") on the kinds that
    have one.
    """
    if dropout:
        cfg = adapters.apply_dropout(spec, cfg, dropout)
    if engine:
        cfg = adapters.apply_engine(spec, cfg, engine)
    init_fn, p_shapes, p_shard, _ = param_setup(spec, cfg, mesh, rules)
    rep = replicated(mesh)

    if shape.kind == "train":
        opt = default_opt(cfg)
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_shard = opt_state_shardings(o_shapes, p_shard, mesh)
        b_specs = adapters.train_batch_specs(spec, cfg, shape)
        b_shard = batch_shardings(spec, cfg, shape, mesh, rules, b_specs)
        fn = make_train_step(spec, cfg, opt, rules, n_micro=n_micro,
                             use_dropout=use_dropout)
        jitted = jax.jit(
            fn,
            in_shardings=(p_shard, o_shard, b_shard, rep, rep),
            out_shardings=(p_shard, o_shard, rep),
            donate_argnums=(0, 1))
        args = (p_shapes, o_shapes, b_specs,
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
        return LoweredCell("train", jitted, args, donate=(0, 1))

    if shape.kind == "prefill":
        state_shapes = adapters.decode_state_specs(
            spec, cfg, shape.global_batch, shape.seq_len)
        s_shard = decode_state_shardings(spec, cfg, state_shapes, mesh, rules)
        b_specs = adapters.prefill_batch_specs(spec, cfg, shape)
        b_shard = batch_shardings(spec, cfg, shape, mesh, rules, b_specs)
        fn = make_prefill_step(spec, cfg, rules)
        jitted = jax.jit(fn,
                         in_shardings=(p_shard, b_shard, s_shard),
                         donate_argnums=(2,))
        args = (p_shapes, b_specs, state_shapes)
        return LoweredCell("prefill", jitted, args, donate=(2,))

    # decode
    state_shapes = adapters.decode_state_specs(
        spec, cfg, shape.global_batch, shape.seq_len)
    s_shard = decode_state_shardings(spec, cfg, state_shapes, mesh, rules)
    tok = adapters.decode_token_specs(spec, cfg, shape)
    tok_shard = NamedSharding(
        mesh, shd.logical_to_pspec(("batch", "seq", None)[:len(tok.shape)],
                                   rules, tok.shape, mesh))
    fn = make_serve_step(spec, cfg, rules)
    jitted = jax.jit(fn,
                     in_shardings=(p_shard, s_shard, tok_shard, rep),
                     donate_argnums=(1,))
    args = (p_shapes, state_shapes, tok,
            jax.ShapeDtypeStruct((), jnp.int32))
    return LoweredCell("decode", jitted, args, donate=(1,))
