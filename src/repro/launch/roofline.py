"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), all in seconds-per-step *per chip*
(XLA's post-partitioning module is the per-device program):

  compute    = HLO_FLOPs / peak_FLOP/s          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes_accessed / HBM_bw      (819 GB/s)
  collective = collective_bytes / link_bw       (~50 GB/s/link ICI)

collective_bytes is not in cost_analysis: we parse the optimized HLO and sum
operand/result sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute. all-reduce counts 2x (reduce+broadcast
phases of a ring); others 1x. Cross-pod ("pod"-axis) collectives ride DCN —
reported separately when replica groups span pods.

MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (fwd-only);
ratio MODEL_FLOPS / (HLO_FLOPs x chips) exposes remat/attention/padding
waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12        # bf16 per chip, TPU v5e
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum byte sizes of every tensor literal in an HLO result type."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    total_bytes: float
    count_by_op: dict

    @property
    def total(self):
        return self.total_bytes


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Parse optimized HLO; sum result sizes of collective ops.

    Matches lines like:
      %all-reduce.5 = bf16[4096,512] all-reduce(%x), replica_groups=...
    ``-start`` variants (async) are counted; ``-done`` skipped (same op).
    """
    by_op = {c: 0.0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result type sits between '=' and the op name
        for c in _COLLECTIVES:
            opname = f" {c}(" if f" {c}(" in ls else f" {c}-start("
            if opname in ls and "-done(" not in ls:
                eq = ls.find("=")
                op_at = ls.find(opname)
                if eq < 0 or op_at < eq:
                    continue
                size = _shape_bytes(ls[eq + 1:op_at])
                factor = 2.0 if c == "all-reduce" else 1.0
                by_op[c] += size * factor
                counts[c] += 1
                break
    return CollectiveStats(by_op, sum(by_op.values()), counts)


@dataclasses.dataclass
class Roofline:
    flops: float               # per-device HLO flops
    bytes_accessed: float      # per-device HBM traffic
    coll_bytes: float          # per-device collective bytes
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float = 0.0   # global useful flops
    flops_ratio: float = 0.0   # model_flops / (flops * chips)
    coll_by_op: Optional[dict] = None

    def table_row(self):
        return (f"{self.t_compute * 1e3:9.2f} {self.t_memory * 1e3:9.2f} "
                f"{self.t_collective * 1e3:9.2f}  {self.bottleneck:10s} "
                f"{self.flops_ratio:6.3f}")


def analyze(cost: dict, coll: CollectiveStats, *, chips: int,
            model_flops: float = 0.0) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    t_c = flops / PEAK_FLOPS
    t_m = raw_bytes / HBM_BW
    t_x = coll.total / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bott = max(terms, key=terms.get)
    ratio = model_flops / (flops * chips) if flops and model_flops else 0.0
    return Roofline(flops, raw_bytes, coll.total, t_c, t_m, t_x, bott,
                    model_flops, ratio, coll.bytes_by_op)


def analyze_loop_aware(la, *, chips: int, model_flops: float = 0.0) -> Roofline:
    """Roofline terms from hlo_cost.LoopAwareCost (per-device program)."""
    t_c = la.flops / PEAK_FLOPS
    t_m = la.bytes_accessed / HBM_BW
    t_x = la.collective_bytes / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bott = max(terms, key=terms.get)
    ratio = (model_flops / (la.flops * chips)
             if la.flops and model_flops else 0.0)
    return Roofline(la.flops, la.bytes_accessed, la.collective_bytes,
                    t_c, t_m, t_x, bott, model_flops, ratio,
                    la.collective_by_op)


# ---------------------------------------------------------------------------
# MODEL_FLOPS helpers
# ---------------------------------------------------------------------------


def count_params(shapes_tree) -> int:
    import jax
    return sum(int(_np_prod(l.shape)) for l in jax.tree.leaves(shapes_tree))


def _np_prod(shape):
    n = 1
    for s in shape:
        n *= s
    return n


def active_params(spec, cfg, total_params: int) -> int:
    """MoE: count only top-k experts' share of expert params as active."""
    moe = getattr(cfg, "moe", None)
    if moe is None:
        return total_params
    L, D, F, E = cfg.num_layers, cfg.d_model, cfg.d_ff, moe.num_experts
    expert_params = L * E * 3 * D * F
    active_expert = L * moe.top_k * 3 * D * F
    return total_params - expert_params + active_expert


def model_flops_for(kind: str, n_active: int, tokens: int) -> float:
    """6ND for a train step, 2ND for forward-only (prefill/decode)."""
    return (6.0 if kind == "train" else 2.0) * n_active * tokens
