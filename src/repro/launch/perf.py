import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: lower+compile named experiment variants of the
three chosen cells and report roofline deltas vs the recorded baseline.

    PYTHONPATH=src python -m repro.launch.perf --exp qwen3_flash
    PYTHONPATH=src python -m repro.launch.perf --list
"""

import argparse
import dataclasses
import json
import time

from repro import configs
from repro.configs.shapes import SHAPES
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_mod


def _mixtral_local(cfg):
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, local_shards=16))


def _mixtral_local_flash(cfg):
    cfg = _mixtral_local(cfg)
    return dataclasses.replace(cfg, attn_impl="flash")


def _flash(cfg):
    return dataclasses.replace(cfg, attn_impl="flash")


def _remat_dots(cfg):
    return dataclasses.replace(cfg, remat="dots")


def _flash_remat_dots(cfg):
    return dataclasses.replace(cfg, attn_impl="flash", remat="dots")


def _bigger_chunks(cfg):
    return dataclasses.replace(cfg, q_chunk=2048, kv_chunk=2048)


def _identity_attn(cfg):
    return dataclasses.replace(cfg, attn_impl="identity")


def _best_xla(cfg):
    return dataclasses.replace(cfg, remat="dots", q_chunk=2048,
                               kv_chunk=2048)


def _mixtral_local_dots(cfg):
    return dataclasses.replace(_mixtral_local(cfg), remat="dots")


def _xlstm_dots(cfg):
    return dataclasses.replace(cfg, remat="dots")


def _xlstm_c512(cfg):
    return dataclasses.replace(cfg, chunk=512)


def _xlstm_c1024(cfg):
    return dataclasses.replace(cfg, chunk=1024)


EXPERIMENTS = {
    # cell 3 (memory-bound dense train): Pallas flash attention
    "qwen3_flash": ("qwen3-8b", "train_4k", _flash, {}),
    "qwen3_flash_dots": ("qwen3-8b", "train_4k", _flash_remat_dots, {}),
    "qwen3_dots": ("qwen3-8b", "train_4k", _remat_dots, {}),
    "qwen3_chunks": ("qwen3-8b", "train_4k", _bigger_chunks, {}),
    "qwen3_noattn": ("qwen3-8b", "train_4k", _identity_attn, {}),
    "qwen3_best": ("qwen3-8b", "train_4k", _best_xla, {}),
    "qwen3_dense_dots": ("qwen3-8b", "train_4k", _remat_dots,
                         {"__dense__": True}),
    # cell 2 (collective-bound MoE train): local routing (+ flash)
    "mixtral_local": ("mixtral-8x22b", "train_4k", _mixtral_local, {}),
    "mixtral_local_flash": ("mixtral-8x22b", "train_4k",
                            _mixtral_local_flash, {}),
    "mixtral_local_dots": ("mixtral-8x22b", "train_4k",
                           _mixtral_local_dots, {}),
    "xlstm_dots": ("xlstm-1.3b", "train_4k", _xlstm_dots, {}),
    "xlstm_c512": ("xlstm-1.3b", "train_4k", _xlstm_c512, {}),
    "xlstm_c1024": ("xlstm-1.3b", "train_4k", _xlstm_c1024, {}),
    # cell 1 (paper-representative): pin the sLSTM h carry replicated so
    # the per-step RH compaction gather is local (confirmed 1.21x).
    "xlstm_pinned": ("xlstm-1.3b", "train_4k",
                     lambda c: dataclasses.replace(c, pin_h_carry=True), {}),
    "xlstm_nofsdp": ("xlstm-1.3b", "train_4k", lambda c: c,
                     {"embed": None}),
    # paper-faithful baselines at dense (no-dropout) for the FLOP delta
    "qwen3_dense": ("qwen3-8b", "train_4k", lambda c: c,
                    {"__dense__": True}),
    "minitron_dense": ("minitron-8b", "train_4k", lambda c: c,
                       {"__dense__": True}),
    "gemma_dense": ("gemma-2b", "train_4k", lambda c: c,
                    {"__dense__": True}),
    "xlstm_dense": ("xlstm-1.3b", "train_4k", lambda c: c,
                    {"__dense__": True}),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="results/perf.json")
    ap.add_argument("--baseline", default="results/dryrun.json")
    args = ap.parse_args()

    if args.list or not args.exp:
        for k, (a, s, _, ov) in EXPERIMENTS.items():
            print(f"{k:24s} {a} {s} {ov}")
        return 0

    results = {}
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    base = json.load(open(args.baseline))

    for name in args.exp.split(","):
        arch, shape_name, mutate, overrides = EXPERIMENTS[name]
        spec = configs.get_arch(arch)
        shape = SHAPES[shape_name]
        mesh = mesh_mod.make_production_mesh()
        rule_ov = {k: v for k, v in overrides.items()
                   if not k.startswith("__")}
        rules = shd.rules_for_mesh(mesh, rule_ov)
        use_dropout = not overrides.get("__dense__", False)

        cfg = mutate(spec.full())
        import repro.launch.steps as steps
        cell = steps.build_cell(spec, cfg, shape, mesh, rules,
                                use_dropout=use_dropout)
        t0 = time.time()
        with mesh:
            compiled = cell.jitted.lower(*cell.example_args).compile()
        from repro.launch import hlo_cost, roofline as rf
        la = hlo_cost.analyze_hlo(compiled.as_text())
        n_params = rf.count_params(
            steps.param_setup(spec, cfg, mesh, rules)[1])
        n_active = rf.active_params(spec, cfg, n_params)
        tokens = shape.global_batch * shape.seq_len
        roof = rf.analyze_loop_aware(
            la, chips=mesh.devices.size,
            model_flops=rf.model_flops_for(shape.kind, n_active, tokens))

        bk = f"{arch}|{shape_name}|16x16|sdrop"
        b = base[bk]["roofline"]
        rec = {
            "arch": arch, "shape": shape_name, "exp": name,
            "compile_s": round(time.time() - t0, 1),
            "roofline": {
                "t_compute_s": roof.t_compute, "t_memory_s": roof.t_memory,
                "t_collective_s": roof.t_collective,
                "bottleneck": roof.bottleneck,
                "flops_ratio": roof.flops_ratio,
            },
            "vs_baseline": {
                "compute": roof.t_compute / max(b["t_compute_s"], 1e-12),
                "memory": roof.t_memory / max(b["t_memory_s"], 1e-12),
                "collective": (roof.t_collective
                               / max(b["t_collective_s"], 1e-12)),
            },
        }
        results[name] = rec
        dom_b = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
        dom_n = max(roof.t_compute, roof.t_memory, roof.t_collective)
        print(f"[{name}] compile {rec['compile_s']}s")
        print(f"  baseline: comp {b['t_compute_s']*1e3:8.1f}ms  "
              f"mem {b['t_memory_s']*1e3:9.1f}ms  "
              f"coll {b['t_collective_s']*1e3:9.1f}ms  "
              f"dom {dom_b*1e3:9.1f}ms")
        print(f"  this    : comp {roof.t_compute*1e3:8.1f}ms  "
              f"mem {roof.t_memory*1e3:9.1f}ms  "
              f"coll {roof.t_collective*1e3:9.1f}ms  "
              f"dom {dom_n*1e3:9.1f}ms  ({dom_b/dom_n:.2f}x better)")
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
