"""Training driver: --arch selection, fault-tolerant loop, auto-resume.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-1.3b \
        --smoke --steps 200 --ckpt-dir /tmp/ckpt --resume auto

Fault tolerance in the loop:
  * checkpoint every --ckpt-every steps (sharded npz + manifest);
  * SIGTERM (preemption) triggers a final checkpoint at the step boundary;
  * --resume auto restores the latest complete checkpoint; the data stream
    is a pure function of (seed, step) so no data state is needed;
  * a step-time watchdog logs stragglers (steps slower than
    --straggler-factor x the running median are flagged; on a real fleet
    this feeds the controller that evicts/replaces the slow host).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_mod
from repro import configs, optim
from repro.configs import adapters
from repro.core.dropout_plan import DropoutPlan
from repro.data import synthetic
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod


def make_batch_fn(spec, cfg, batch: int, seq: int, seed: int):
    vocab = getattr(cfg, "vocab", None) or getattr(cfg, "src_vocab", 256)

    if spec.kind in ("transformer", "xlstm", "ssm", "lstm_lm"):
        stream = synthetic.lm_stream(vocab, batch * (seq + 1) * 64, seed=seed)

        def fn(step):
            n = batch * (seq + 1)
            off = (step * n) % (len(stream) - n - 1)
            chunk = stream[off:off + n].reshape(batch, seq + 1)
            d = {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}
            if getattr(cfg, "embeds_in", False):
                rng = np.random.default_rng(seed + step)
                d["embeds"] = rng.standard_normal(
                    (batch, seq, cfg.d_model), dtype=np.float32)
                del d["tokens"]
            if getattr(cfg, "is_encoder_decoder", False):
                rng = np.random.default_rng(seed + step)
                d["frames"] = rng.standard_normal(
                    (batch, cfg.enc_seq, cfg.d_model),
                    dtype=np.float32) * 0.02
            return d
        return fn
    if spec.kind == "nmt":
        def fn(step):
            return synthetic.nmt_pairs(batch, cfg.src_vocab, cfg.tgt_vocab,
                                       max_len=seq, seed=seed + step)
        return fn
    if spec.kind == "tagger":
        def fn(step):
            return synthetic.ner_examples(batch, cfg.vocab, cfg.char_vocab,
                                          cfg.num_tags, seq=seq,
                                          seed=seed + step)
        return fn
    raise ValueError(spec.kind)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--no-dropout", action="store_true")
    ap.add_argument("--dropout", default="",
                    help="dropout-plan override: 'case{1..4}:<rate>[:bs<int>]"
                         "[:pallas]' (e.g. case3:0.5:bs128) or 'off'; applies "
                         "the case at the arch's canonical sites")
    ap.add_argument("--engine", default="",
                    choices=["", "scheduled", "stepwise", "fused"],
                    help="recurrent-engine override: 'scheduled' (two-phase: "
                         "masks + NR matmuls hoisted out of the scan), "
                         "'fused' (Phase B as one persistent-scan kernel "
                         "per layer) or 'stepwise' (in-scan reference); "
                         "applies to the recurrent archs, no-op elsewhere")
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--mesh", default="",
                    help="data-parallel sharded training: 'auto' (all host "
                         "devices) or an int device count. Runs the step "
                         "under shard_map — batch sharded over 'data', "
                         "params/U replicated, grads psum'd exactly "
                         "(docs/distributed.md). Recurrent archs only; "
                         "--batch must divide by the mesh size")
    args = ap.parse_args(argv)

    spec = configs.get_arch(args.arch)
    cfg = spec.smoke() if args.smoke else spec.full()
    if args.dropout:
        cfg = adapters.apply_dropout(spec, cfg, args.dropout)
        print(f"[dropout] plan override {args.dropout!r} -> sites "
              f"{list(cfg.plan.active_sites())}")
    if args.engine:
        cfg = adapters.apply_engine(spec, cfg, args.engine)
        if spec.kind in adapters.ENGINE_KINDS:
            print(f"[engine] recurrent engine -> {cfg.engine!r}")
    if args.mesh:
        n_dev = (len(jax.devices()) if args.mesh == "auto"
                 else int(args.mesh))
        mesh = mesh_mod.make_data_mesh(n_dev)
        print(f"[mesh] data-parallel over {n_dev} device(s)")
    else:
        mesh = mesh_mod.make_host_mesh()
    rules = shd.rules_for_mesh(mesh)

    init_fn, p_shapes, p_shard, _ = steps_mod.param_setup(
        spec, cfg, mesh, rules, seed=args.seed)
    opt = optim.chain(optim.clip_by_global_norm(1.0), optim.adamw(args.lr))
    if args.mesh:
        train_step = steps_mod.make_sharded_train_step(
            spec, cfg, opt, mesh, rules=rules,
            use_dropout=not args.no_dropout)
    else:
        train_step = steps_mod.make_train_step(
            spec, cfg, opt, rules, use_dropout=not args.no_dropout)
    jitted = jax.jit(train_step, donate_argnums=(0, 1))

    params = init_fn()
    opt_state = opt.init(params)
    start = 0

    hook = ckpt_mod.PreemptionHook()
    if args.ckpt_dir and args.resume == "auto":
        latest = ckpt_mod.latest_step(args.ckpt_dir)
        if latest is not None:
            (params, opt_state), start = ckpt_mod.restore_checkpoint(
                args.ckpt_dir, (params, opt_state))
            print(f"[resume] restored step {start} from {args.ckpt_dir}")

    batch_fn = make_batch_fn(spec, cfg, args.batch, args.seq, args.seed)
    key = jax.random.PRNGKey(args.seed)
    # record the pattern that actually RAN: --no-dropout withholds the key,
    # so every site is inactive regardless of the config's plan
    ckpt_meta = None
    if hasattr(cfg, "plan"):
        plan_ran = DropoutPlan.off() if args.no_dropout else cfg.plan
        ckpt_meta = {"dropout_plan": plan_ran.to_dict()}
    times = []
    loss = float("nan")   # resume past end of run: no step executes
    t_train0 = time.time()
    for step in range(start, args.steps):
        t0 = time.time()
        batch = jax.tree.map(jnp.asarray, batch_fn(step))
        params, opt_state, loss = jitted(
            params, opt_state, batch, jnp.int32(step),
            jax.random.fold_in(key, step))
        loss = float(loss)
        dt = time.time() - t0
        times.append(dt)
        med = float(np.median(times[-50:]))
        if dt > args.straggler_factor * med and len(times) > 10:
            print(f"[straggler] step {step} took {dt:.2f}s "
                  f"(median {med:.2f}s) — flagged for controller")
        if step % args.log_every == 0:
            print(f"step {step:5d}  loss {loss:.4f}  {dt*1e3:.0f} ms")
        do_ckpt = args.ckpt_dir and (
            (step + 1) % args.ckpt_every == 0 or hook.should_save
            or step + 1 == args.steps)
        if do_ckpt:
            ckpt_mod.save_checkpoint(args.ckpt_dir, step + 1,
                                     (params, opt_state), meta=ckpt_meta)
            if hook.should_save:
                print(f"[preempt] final checkpoint at step {step+1}; exiting")
                return 0
    total = time.time() - t_train0
    n_run = max(args.steps - start, 0)
    print(f"done: {n_run} steps in {total:.1f}s "
          f"({n_run/max(total,1e-9):.2f} steps/s), "
          f"final loss {loss:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
