"""Loop-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE, so for
scan-over-layers models it undercounts FLOPs/bytes/collectives by ~L x (and
by the time-scan length for recurrent cells). This module re-derives the
three roofline inputs from the HLO text with loop multipliers applied:

  1. parse computations + a module-wide name->shape map;
  2. find every `while` op, resolve its body/cond computations, read the
     trip count (the s32 constant in the cond — jax scans count 0..N);
  3. build the call graph (while bodies, fusion `calls=`, to_apply) and
     propagate execution multipliers from ENTRY;
  4. accumulate per-computation:
       * dot FLOPs (2 * prod(result) * prod(contracting dims)),
       * HBM bytes ~ operands+result of traffic ops (dot / fusion /
         collectives / copy / slice / gather / scatter / reduce / cumsum),
       * collective bytes (all-reduce 2x ring factor).

FLOPs are exact for matmul-dominated programs; bytes are a fusion-level
approximation (CPU-backend fusion differs from TPU — stated in
EXPERIMENTS.md methodology); collective bytes are exact per occurrence.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
_CALLS_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# TPU-oriented HBM-traffic model per opcode (CPU-backend fusion differs from
# TPU, so: elementwise/convert/select/compare are assumed fused => free;
# bitcast/reshape are layout-free; slicing counts the slice, not the buffer).
#   key -> (count_result_x, count_operands)
_TRAFFIC_MODEL = {
    "dot": (1.0, True),
    "fusion": (1.0, True),            # operands slice-capped, see below
    # copy/broadcast: host-backend loop-aliasing & mask-materialization
    # artifacts — TPU fuses these into consumers; excluded from the model.
    "transpose": (1.0, False),
    "dynamic-slice": (2.0, False),
    "slice": (2.0, False),
    "gather": (2.0, False),
    "pad": (2.0, False),
    "concatenate": (2.0, False),
    "reduce": (0.0, True),
    "reduce-window": (1.0, True),
    "sort": (2.0, True),
    "convolution": (1.0, True),
    "rng-bit-generator": (1.0, False),
    "all-reduce": (2.0, False),
    "all-gather": (2.0, False),
    "reduce-scatter": (2.0, False),
    "all-to-all": (2.0, False),
    "collective-permute": (2.0, False),
    "all-reduce-start": (2.0, False),
    "all-gather-start": (2.0, False),
    "collective-permute-start": (2.0, False),
    "scatter": (0.0, None),           # special-cased: 2 x updates operand
    "dynamic-update-slice": (0.0, None),  # special-cased
}

# operands larger than this multiple of the result are assumed to be
# sliced/gathered inside the fusion (stacked scan weights) — cap at result.
_SLICE_CAP = 8.0

# Opcodes that are pure element-glue: a fusion made only of these would fuse
# into its producer/consumer on TPU, so we charge its RESULT once (the one
# materialization) instead of operands+result.
_ELEMENTWISE = frozenset((
    "add", "subtract", "multiply", "divide", "negate", "abs", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "tanh", "logistic",
    "sqrt", "rsqrt", "power", "maximum", "minimum", "compare", "select",
    "and", "or", "xor", "not", "convert", "copy", "bitcast", "broadcast",
    "constant", "parameter", "iota", "reshape", "tuple", "get-tuple-element",
    "clamp", "sign", "floor", "ceil", "round-nearest-afz", "is-finite",
    "reduce-precision", "cosine", "sine", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "xor", "popcnt",
    "remainder", "atan2", "expm1", "log1p", "slice", "transpose", "pad",
))

_OPCODE_RE = re.compile(r"(?:^|\s|\})([a-z][a-z0-9\-]*)\(")


def _shapes_of(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((dt, dims))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes_of(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    body: str          # everything right of '='


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_entry: bool = False


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and ("->" in line):
                cur = Computation(m.group(1), [],
                                  is_entry=line.startswith("ENTRY"))
                if cur.is_entry:
                    entry = cur.name
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if dm:
            rest = dm.group(2)
            # split result type from op call: type is everything before the
            # first opcode token; find " <opname>(" boundary
            cur.ops.append(Op(dm.group(1), rest, rest))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _result_type(op_body: str) -> str:
    # "f32[2,64]{1,0} dot(%a, %b), ..." -> up to the op name
    i = op_body.find("(")
    if i < 0:
        return op_body
    head = op_body[:i]
    j = head.rfind(" ")
    return head[:j] if j > 0 else head


def _opcode(op_body: str) -> str:
    """Opcode token immediately before the first '(' (not metadata text)."""
    i = op_body.find("(")
    if i < 0:
        return ""
    head = op_body[:i]
    toks = head.split()
    return toks[-1].lstrip("%") if toks else ""


def _name_shapes(comps: Dict[str, Computation]) -> Dict[str, str]:
    """Global op-name -> result-type string (HLO names are module-unique)."""
    out = {}
    for c in comps.values():
        for op in c.ops:
            out[op.name] = _result_type(op.body)
    return out


def _operands(op_body: str) -> List[str]:
    i = op_body.find("(")
    j = op_body.find(")", i)
    if i < 0 or j < 0:
        return []
    args = op_body[i + 1:j]
    return re.findall(r"%([\w.\-]+)", args)


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    res = _shapes_of(_result_type(op.body))
    if not res:
        return 0.0
    _, rdims = res[0]
    n_out = 1
    for d in rdims:
        n_out *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.body)
    ops = _operands(op.body)
    if not m or not ops:
        return 0.0
    lhs_type = shapes.get(ops[0], "")
    lshapes = _shapes_of(lhs_type)
    if not lshapes:
        return 0.0
    _, ldims = lshapes[0]
    k = 1
    for idx in (int(x) for x in m.group(1).split(",") if x):
        if idx < len(ldims):
            k *= ldims[idx]
    return 2.0 * n_out * k


@dataclasses.dataclass
class LoopAwareCost:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collective_by_op: Dict[str, float]
    collective_counts: Dict[str, float]
    while_trips: List[int]
    # debug/perf-loop aids: top individual (computation, op, opcode) by bytes
    top_bytes: Optional[List] = None
    bytes_by_opcode: Optional[Dict[str, float]] = None
    flops_by_metadata: Optional[List] = None

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "collective_by_op": self.collective_by_op,
            "collective_counts": self.collective_counts,
        }


def _is_glue(comp: Computation) -> bool:
    """True when a fusion callee contains only elementwise/layout opcodes."""
    for op in comp.ops:
        opc = _opcode(op.body)
        if opc and opc not in _ELEMENTWISE:
            return False
    return True


def analyze_hlo(text: str) -> LoopAwareCost:
    comps, entry = parse_module(text)
    shapes = _name_shapes(comps)
    glue = {name for name, c in comps.items() if _is_glue(c)}

    # --- trip counts: cond computation -> s32 constant bound
    trip_of_cond: Dict[str, int] = {}
    for c in comps.values():
        consts = []
        for op in c.ops:
            consts += [int(x) for x in _CONST_RE.findall(op.body)]
        if consts:
            trip_of_cond[c.name] = max(consts)

    # --- call edges with multipliers
    # edges[comp] = list of (callee, mult) — while body gets trips, else 1
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    for c in comps.values():
        for op in c.ops:
            if " while(" in op.body:
                mb = re.search(r"body=%?([\w.\-]+)", op.body)
                mc = re.search(r"condition=%?([\w.\-]+)", op.body)
                trips = trip_of_cond.get(mc.group(1), 1) if mc else 1
                if mb:
                    edges[c.name].append((mb.group(1), float(max(trips, 1))))
                if mc:
                    edges[c.name].append((mc.group(1), float(max(trips, 1))))
            else:
                for callee in _CALLS_RE.findall(op.body):
                    if callee in comps:
                        edges[c.name].append((callee, 1.0))

    # --- propagate multipliers from entry in topological order
    # (the HLO call graph is a DAG, so one pass suffices)
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry] = 1.0
    for c in _topo_order(comps, edges, entry):
        for callee, k in edges[c]:
            mult[callee] += mult[c] * k

    # --- accumulate costs
    flops = 0.0
    nbytes = 0.0
    coll = {c: 0.0 for c in _COLLECTIVES}
    coll_n = {c: 0.0 for c in _COLLECTIVES}
    trips_seen = sorted({int(t) for t in trip_of_cond.values()})
    top: Dict[tuple, float] = {}
    by_opc: Dict[str, float] = {}

    def _acc(c_name, op, opc, amount):
        nonlocal nbytes
        nbytes += amount
        by_opc[opc] = by_opc.get(opc, 0.0) + amount
        k = (c_name, op.name, opc)
        top[k] = top.get(k, 0.0) + amount

    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if m <= 0.0:
            continue
        for op in c.ops:
            body = op.body
            opc = _opcode(body)
            if opc == "dot":
                flops += m * _dot_flops(op, shapes)

            base = opc[:-6] if opc.endswith("-start") else opc
            if base in _COLLECTIVES:
                size = _nbytes(_result_type(body))
                factor = 2.0 if base == "all-reduce" else 1.0
                coll[base] += m * size * factor
                coll_n[base] += m

            model = _TRAFFIC_MODEL.get(opc)
            if model is None:
                continue
            res = _nbytes(_result_type(body))
            if opc == "dynamic-update-slice":
                ops_ = _operands(body)
                upd = _nbytes(shapes.get(ops_[1], "")) if len(ops_) > 1 else 0
                _acc(c.name, op, opc, m * 2.0 * upd)
                continue
            if opc == "scatter":
                ops_ = _operands(body)
                upd = _nbytes(shapes.get(ops_[-1], "")) if ops_ else 0
                _acc(c.name, op, opc, m * 2.0 * upd)
                continue
            if opc == "fusion" and "dynamic-update-slice" in op.name:
                # in-place insert: traffic = 2 x the (small) update operands;
                # the aliased buffer result is NOT rewritten.
                size = 0.0
                for o in _operands(body):
                    ob = _nbytes(shapes.get(o, ""))
                    if res == 0 or ob <= res / _SLICE_CAP:
                        size += 2.0 * ob
                _acc(c.name, op, "dus-fusion", m * size)
                continue
            if opc == "fusion":
                callee = _CALLS_RE.search(body)
                if callee and callee.group(1) in glue:
                    # elementwise glue: charge the single materialization
                    _acc(c.name, op, "glue-fusion", m * float(res))
                    continue
            res_x, count_ops = model
            size = res_x * res
            if count_ops:
                for o in _operands(body):
                    ob = _nbytes(shapes.get(o, ""))
                    if opc == "fusion" and res > 0 and ob > _SLICE_CAP * res:
                        ob = res       # assume sliced/gathered inside
                    size += ob
            _acc(c.name, op, opc, m * size)

    top_list = sorted(top.items(), key=lambda kv: -kv[1])[:20]
    return LoopAwareCost(flops, nbytes, sum(coll.values()), coll, coll_n,
                         trips_seen,
                         top_bytes=[(k[0][:48], k[1][:48], k[2], v)
                                    for k, v in top_list],
                         bytes_by_opcode=by_opc)


def _topo_order(comps, edges, entry):
    seen, order = set(), []

    def dfs(c):
        if c in seen:
            return
        seen.add(c)
        for callee, _ in edges.get(c, ()):
            dfs(callee)
        order.append(c)

    dfs(entry)
    return list(reversed(order))
