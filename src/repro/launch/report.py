"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.json.

    PYTHONPATH=src python -m repro.launch.report results/dryrun.json
"""
from __future__ import annotations

import json
import sys

from repro.launch.roofline import PEAK_FLOPS

ARCH_ORDER = ["xlstm-1.3b", "mixtral-8x22b", "arctic-480b", "qwen3-8b",
              "minitron-8b", "gemma-2b", "qwen1.5-32b", "pixtral-12b",
              "zamba2-1.2b", "whisper-base"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_t(s):
    if s == 0:
        return "0"
    if s < 1e-3:
        return f"{s*1e6:.0f}us"
    if s < 1:
        return f"{s*1e3:.1f}ms"
    return f"{s:.2f}s"


def roofline_table(data: dict, mesh: str = "16x16", variant: str = "sdrop"):
    lines = [
        "| arch | shape | kind | t_compute | t_memory | t_collective | "
        "bottleneck | MODEL_FLOPS/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            k = f"{arch}|{shape}|{mesh}|{variant}"
            r = data.get(k)
            if r is None:
                continue
            if r["status"] == "skip":
                lines.append(f"| {arch} | {shape} | — | — | — | — | "
                             f"skip | — | — |")
                continue
            ro = r["roofline"]
            dom = max(ro["t_compute_s"], ro["t_memory_s"],
                      ro["t_collective_s"])
            # roofline fraction: useful-compute time / dominant term
            t_useful = (ro["model_flops"] / r["chips"]) / PEAK_FLOPS
            frac = t_useful / dom if dom > 0 else 0.0
            lines.append(
                f"| {arch} | {shape} | {r['kind']} | "
                f"{fmt_t(ro['t_compute_s'])} | {fmt_t(ro['t_memory_s'])} | "
                f"{fmt_t(ro['t_collective_s'])} | {ro['bottleneck']} | "
                f"{ro['flops_ratio']:.3f} | {frac:.3f} |")
    return "\n".join(lines)


def dryrun_table(data: dict, variant: str = "sdrop"):
    lines = [
        "| arch | shape | mesh | params | bytes/dev (args+temp) | "
        "HLO flops/dev | coll bytes/dev | compile |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                k = f"{arch}|{shape}|{mesh}|{variant}"
                r = data.get(k)
                if r is None or r["status"] == "skip":
                    if r is not None and mesh == "16x16":
                        lines.append(f"| {arch} | {shape} | both | — | skip: "
                                     f"{r['reason'][:60]}… | | | |")
                    continue
                mem = r.get("memory", {})
                per_dev = (mem.get("argument_size_in_bytes", 0)
                           + mem.get("temp_size_in_bytes", 0)) \
                    / r["chips"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} | "
                    f"{r['params']/1e9:.2f}B | {fmt_bytes(per_dev)} | "
                    f"{r['cost']['flops']:.2e} | "
                    f"{fmt_bytes(r['cost']['collective_bytes'])} | "
                    f"{r['compile_s']}s |")
    return "\n".join(lines)


def pick_hillclimb(data: dict, mesh="16x16", variant="sdrop"):
    """worst roofline fraction / most collective-bound / most paper-like."""
    worst, coll = None, None
    for k, r in data.items():
        if r.get("status") != "ok" or f"|{mesh}|" not in k:
            continue
        ro = r["roofline"]
        dom = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        t_useful = (ro["model_flops"] / r["chips"]) / PEAK_FLOPS
        frac = t_useful / dom if dom else 0
        if worst is None or frac < worst[1]:
            worst = (k, frac)
        cfrac = ro["t_collective_s"] / dom if dom else 0
        if coll is None or cfrac > coll[1]:
            coll = (k, cfrac)
    return worst, coll


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    data = json.load(open(path))
    print("## Roofline (single-pod 16x16, per-device terms)\n")
    print(roofline_table(data))
    print("\n## Dry-run (both meshes)\n")
    print(dryrun_table(data))
    worst, coll = pick_hillclimb(data)
    print(f"\nworst roofline fraction: {worst[0]} ({worst[1]:.4f})")
    print(f"most collective-bound:  {coll[0]} ({coll[1]:.2f} of dominant)")


if __name__ == "__main__":
    main()
