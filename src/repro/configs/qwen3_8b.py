"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B]."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_SKIP
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec
from repro.models.transformer import TransformerConfig


def full(**kw):
    d = dict(
        name="qwen3-8b", num_layers=36, d_model=4096, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=12288, vocab=151936,
        qk_norm=True, mlp="swiglu", rope_theta=1e6, max_seq=1 << 20,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        kv_repeat=2, q_chunk=1024, kv_chunk=1024,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=128)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


def smoke(**kw):
    d = dict(
        name="qwen3-smoke", num_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=128, qk_norm=True,
        q_chunk=8, kv_chunk=8, max_seq=64,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=8)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


SPEC = ArchSpec(
    name="qwen3-8b", family="dense", kind="transformer", full=full,
    smoke=smoke, skip_shapes={"long_500k": FULL_ATTN_SKIP})
