"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron, squared-ReLU MLP [arXiv:2407.14679]."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_SKIP
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec
from repro.models.transformer import TransformerConfig


def full(**kw):
    d = dict(
        name="minitron-8b", num_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=16384, vocab=256000,
        mlp="relu2", max_seq=1 << 20,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        kv_repeat=2, q_chunk=1024, kv_chunk=1024,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=128)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


def smoke(**kw):
    d = dict(
        name="minitron-smoke", num_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=128, mlp="relu2",
        q_chunk=8, kv_chunk=8, max_seq=64,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=8)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


SPEC = ArchSpec(
    name="minitron-8b", family="dense", kind="transformer", full=full,
    smoke=smoke, skip_shapes={"long_500k": FULL_ATTN_SKIP})
