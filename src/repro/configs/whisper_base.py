"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 — enc-dec, conv frontend STUB (input_specs provides precomputed
frame embeddings) [arXiv:2212.04356]."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_SKIP
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec
from repro.models.transformer import TransformerConfig


def full(**kw):
    d = dict(
        name="whisper-base", num_layers=6, enc_layers=6, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865,
        is_encoder_decoder=True, enc_seq=1500, norm="layernorm",
        pos="sinusoidal", mlp="gelu_mlp", max_seq=1 << 20,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        kv_repeat=1,                   # MHA (8 q = 8 kv): no headroom to
        q_chunk=1024, kv_chunk=1024,   # repeat; heads fall back to flat shard
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=64)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


def smoke(**kw):
    d = dict(
        name="whisper-smoke", num_layers=2, enc_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
        is_encoder_decoder=True, enc_seq=12, norm="layernorm",
        pos="sinusoidal", mlp="gelu_mlp", q_chunk=8, kv_chunk=8, max_seq=64,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=8)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


SPEC = ArchSpec(
    name="whisper-base", family="audio", kind="transformer", full=full,
    smoke=smoke, skip_shapes={"long_500k": FULL_ATTN_SKIP},
    notes="conv audio frontend is a stub per assignment; decoder shapes use "
          "self-KV cache + precomputed cross-KV over 1500 encoder frames")
