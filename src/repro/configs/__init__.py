"""Architecture registry: ``--arch <id>`` -> ArchSpec.

10 assigned archs (public pool) + the paper's own 5 configs.
"""
from __future__ import annotations

from repro.configs import (arctic_480b, gemma_2b, minitron_8b, mixtral_8x22b,
                           pixtral_12b, qwen1_5_32b, qwen3_8b, whisper_base,
                           xlstm_1_3b, zamba2_1_2b)
from repro.configs import paper_models
from repro.configs.base import ArchSpec
from repro.configs.shapes import SHAPES, SHAPE_NAMES, ShapeSpec

ASSIGNED = [
    xlstm_1_3b.SPEC,
    mixtral_8x22b.SPEC,
    arctic_480b.SPEC,
    qwen3_8b.SPEC,
    minitron_8b.SPEC,
    gemma_2b.SPEC,
    qwen1_5_32b.SPEC,
    pixtral_12b.SPEC,
    zamba2_1_2b.SPEC,
    whisper_base.SPEC,
]

REGISTRY = {s.name: s for s in ASSIGNED + paper_models.PAPER_SPECS}

ASSIGNED_NAMES = tuple(s.name for s in ASSIGNED)


def get_arch(name: str) -> ArchSpec:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def all_cells():
    """The 40 assigned (arch x shape) cells, with skip reasons resolved."""
    for spec in ASSIGNED:
        for sname in SHAPE_NAMES:
            yield spec, SHAPES[sname], spec.applicable(sname)
