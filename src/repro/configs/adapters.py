"""Uniform model API over every arch kind (used by launch/, tests, benches).

  loss_fn(kind)        (params, batch, cfg, *, rules, drop_key, step) -> loss
  init_params(kind)    (key, cfg) -> Param-tagged pytree
  prefill_fn / decode_fn / init_decode_state — serving entry points
  input_specs(spec, cfg, shape) — ShapeDtypeStruct stand-ins for every model
  input of that (arch x shape) cell: weak-type-correct, shardable, no device
  allocation. This is what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.configs.shapes import ShapeSpec
from repro.core.dropout_plan import DropoutPlan
from repro.core.lstm import ENGINES
from repro.models import lstm_lm, seq2seq, ssm, tagger, transformer, xlstm

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# dispatch tables
# ---------------------------------------------------------------------------

_MODULES = {
    "transformer": transformer,
    "xlstm": xlstm,
    "ssm": ssm,
    "lstm_lm": lstm_lm,
    "nmt": seq2seq,
    "tagger": tagger,
}


def module(kind: str):
    return _MODULES[kind]


def init_params(kind: str, key, cfg):
    return _MODULES[kind].init_params(key, cfg)


def loss_fn(kind: str):
    return _MODULES[kind].loss_fn


# ---------------------------------------------------------------------------
# dropout-plan overrides (the --dropout flag)
# ---------------------------------------------------------------------------

# Canonical application sites per arch kind: what a CLI case override like
# ``case3:0.5:bs128`` turns on. Site names resolve hierarchically (the
# models consume e.g. "enc/layer0/nr" against the "nr" entry).
DROPOUT_SITES = {
    "lstm_lm": ("embed", "nr", "rh", "out"),
    "nmt": ("nr", "rh", "out"),
    "tagger": ("inp", "rh"),
    "transformer": ("nr",),
    "xlstm": ("nr", "rh"),
    "ssm": ("nr",),
}


def dropout_override(kind: str, text: str) -> DropoutPlan:
    """Parse a CLI override ("case3:0.5:bs128" | "off") into a plan that
    covers the kind's canonical sites."""
    return DropoutPlan.parse(text, sites=DROPOUT_SITES[kind])


def apply_dropout(spec: ArchSpec, cfg, text: str):
    """Return cfg with its plan replaced by the parsed CLI override."""
    if not text:
        return cfg
    return dataclasses.replace(cfg, plan=dropout_override(spec.kind, text))


# ---------------------------------------------------------------------------
# recurrent-engine overrides (the --engine flag, mirroring --dropout).
# ENGINES (the valid names) is owned by repro.core.lstm.
# ---------------------------------------------------------------------------

# Kinds with a time-recurrent scan the engine knob applies to. The depth-
# scanned kinds (transformer, ssm, and xlstm's mLSTM blocks) have no
# sequential NR dependence to hoist — they are already "scheduled".
ENGINE_KINDS = ("lstm_lm", "nmt", "tagger", "xlstm")


def apply_engine(spec: ArchSpec, cfg, text: str):
    """Return cfg with its recurrent engine replaced by the CLI override.

    ``""`` keeps the config's engine; non-recurrent kinds ignore the
    override (there is no scan engine to select).
    """
    if not text:
        return cfg
    if text not in ENGINES:
        raise ValueError(f"unknown engine {text!r}; expected one of {ENGINES}")
    if spec.kind not in ENGINE_KINDS:
        return cfg
    return dataclasses.replace(cfg, engine=text)


# ---------------------------------------------------------------------------
# distributed loss weights (the shard_map data-parallel path)
# ---------------------------------------------------------------------------

# Kinds wired through launch/steps.py::make_sharded_train_step. Matches
# ENGINE_KINDS: the recurrent families whose loss_fn accepts the ``shard``
# kwarg (shard-safe dense masks — see core/dropout_plan.py).
SHARD_KINDS = ENGINE_KINDS


def loss_weight(kind: str):
    """Weight of ``loss_fn(kind)``'s mean for one batch, as an f32 scalar.

    Every kind's loss is a weighted mean ``sum(elems * m) / max(sum(m), 1)``
    (clamped so all-dummy batches yield 0.0, see core/metrics.masked_mean).
    The returned fn computes ``sum(m)`` — exactly the denominator the
    unsharded loss divides by — so the data-parallel combination

        global_loss = psum(local_loss * local_w) / max(psum(local_w), 1)

    reproduces the single-device loss bit-for-bit in exact arithmetic,
    ragged batches and all-pad shards included (distributed/data_parallel.py).
    """
    if kind in ("lstm_lm", "xlstm"):
        def w(batch, cfg):
            B, S = batch["tokens"].shape
            if "lengths" in batch:
                from repro.core import metrics
                return metrics.length_mask(batch["lengths"], S).sum()
            return jnp.float32(B * S)
        return w
    if kind == "nmt":
        def w(batch, cfg):
            B, S = batch["tgt_in"].shape
            mask = batch.get("tgt_mask")
            if mask is None and "tgt_lengths" in batch:
                from repro.core import metrics
                mask = metrics.length_mask(batch["tgt_lengths"], S)
            if mask is not None:
                return mask.astype(jnp.float32).sum()
            return jnp.float32(B * S)
        return w
    if kind == "tagger":
        def w(batch, cfg):
            if "lengths" in batch:
                return (batch["lengths"] > 0).astype(jnp.float32).sum()
            return jnp.float32(batch["tags"].shape[0])
        return w
    raise ValueError(f"{kind} has no sharded-loss weight; "
                     f"supported: {SHARD_KINDS}")


# ---------------------------------------------------------------------------
# training / prefill batch specs
# ---------------------------------------------------------------------------


# Kinds whose loss_fn consumes per-row length columns (the token-packed
# ragged path: lengths freeze recurrent carries in-kernel and derive the
# masked loss — see core/metrics.py and data/pipeline.py::PackedBatcher).
RAGGED_KINDS = ("lstm_lm", "nmt", "tagger", "xlstm")

# The length column(s) a ragged batch of each kind carries.
RAGGED_KEYS = {
    "lstm_lm": ("lengths",),
    "xlstm": ("lengths",),
    "tagger": ("lengths",),
    "nmt": ("src_lengths", "tgt_lengths"),
}


def train_batch_specs(spec: ArchSpec, cfg, shape: ShapeSpec, *,
                      ragged: bool = False):
    """Batch leaf specs for one (arch x shape) cell.

    ``ragged=True`` adds the kind's length column(s) — (B,) int32 — for
    token-packed batches (only ``RAGGED_KINDS`` support them)."""
    B, S = shape.global_batch, shape.seq_len
    if spec.kind == "transformer":
        d: dict = {"labels": _sds((B, S), I32)}
        if getattr(cfg, "embeds_in", False):
            d["embeds"] = _sds((B, S, cfg.d_model), cfg.compute_dtype)
        else:
            d["tokens"] = _sds((B, S), I32)
        if getattr(cfg, "is_encoder_decoder", False):
            d["frames"] = _sds((B, cfg.enc_seq, cfg.d_model),
                               cfg.compute_dtype)
    elif spec.kind in ("xlstm", "ssm"):
        d = {"tokens": _sds((B, S), I32), "labels": _sds((B, S), I32)}
    elif spec.kind == "lstm_lm":
        d = {"tokens": _sds((B, S), I32), "labels": _sds((B, S), I32)}
    elif spec.kind == "nmt":
        d = {"src": _sds((B, S), I32), "tgt_in": _sds((B, S), I32),
             "tgt_out": _sds((B, S), I32)}
    elif spec.kind == "tagger":
        d = {"words": _sds((B, S), I32),
             "chars": _sds((B, S, 12), I32),
             "tags": _sds((B, S), I32),
             "mask": _sds((B, S), jnp.bool_)}
    else:
        raise ValueError(spec.kind)
    if ragged:
        if spec.kind not in RAGGED_KINDS:
            raise ValueError(f"{spec.kind} has no ragged (length-column) "
                             f"path; supported: {RAGGED_KINDS}")
        for k in RAGGED_KEYS[spec.kind]:
            d[k] = _sds((B,), I32)
    return d


def batch_logical_axes(spec: ArchSpec, cfg, shape: ShapeSpec):
    """Logical axes per batch leaf (-> PartitionSpecs via sharding rules)."""
    def ax(leaf_shape_len, has_feat=False):
        base = [("batch",), ("batch", "seq"), ("batch", "seq", None),
                ("batch", "seq", None, None)]
        return base[leaf_shape_len - 1]

    specs = train_batch_specs(spec, cfg, shape)
    return {k: ax(len(v.shape)) for k, v in specs.items()}


# ---------------------------------------------------------------------------
# serving specs
# ---------------------------------------------------------------------------


def init_decode_state(spec: ArchSpec, cfg, batch: int, max_seq: int):
    if spec.kind == "transformer":
        return transformer.init_cache(cfg, batch, max_seq)
    if spec.kind == "xlstm":
        return xlstm.init_state(cfg, batch)
    if spec.kind == "ssm":
        return ssm.init_state(cfg, batch, max_seq=max_seq)
    if spec.kind == "nmt":
        # max_seq caps the resident encoder memory (attention span)
        return seq2seq.init_state(cfg, batch, max_src=max_seq)
    raise ValueError(f"{spec.kind} has no decode path")


def decode_state_specs(spec: ArchSpec, cfg, batch: int, max_seq: int):
    return jax.eval_shape(
        lambda: init_decode_state(spec, cfg, batch, max_seq))


def decode_fn(spec: ArchSpec):
    if spec.kind == "transformer":
        return transformer.decode_step
    if spec.kind == "xlstm":
        return xlstm.decode_step
    if spec.kind == "ssm":
        return ssm.decode_step
    if spec.kind == "nmt":
        return seq2seq.decode_step
    raise ValueError(f"{spec.kind} has no decode path")


def has_native_prefill(spec: ArchSpec) -> bool:
    """True when ``prefill_fn`` really fills the decode state in one
    rectangular pass (transformer KV, xlstm recurrent prefill). ssm's
    forward emits features only — its serving prefill is the shared
    masked-replay helper (serving/prefill.py)."""
    return spec.kind in ("transformer", "xlstm", "nmt")


def decode_state_shardings(spec: ArchSpec, cfg, rules, mesh, batch: int,
                           max_seq: int):
    """NamedSharding tree for the serving decode state: slots/batch over
    ("pod", "data"), kv-heads over "model" — the serving mirror of the
    training param/batch sharding (non-divisible dims replicate)."""
    from repro.distributed import sharding as shd
    shapes = decode_state_specs(spec, cfg, batch, max_seq)
    return shd.make_shardings(decode_state_axes(spec, cfg), rules, mesh,
                              shapes)


def prefill_fn(spec: ArchSpec):
    """(params, batch, cfg, state, rules) -> (feats_or_logits, state)."""
    if spec.kind == "transformer":
        def f(params, batch, cfg, cache, rules=None):
            memory = None
            if getattr(cfg, "is_encoder_decoder", False):
                memory = transformer.encode(params, batch["frames"], cfg,
                                            rules=rules)
            inputs = (batch["embeds"] if getattr(cfg, "embeds_in", False)
                      else batch["tokens"])
            return transformer.prefill(params, inputs, cfg, cache,
                                       rules=rules, memory=memory)
        return f
    if spec.kind == "xlstm":
        def f(params, batch, cfg, state, rules=None):
            # real recurrent prefill: fills the mLSTM (C, n, m) + conv and
            # sLSTM (h, c, n, m) serving state from the prompt, so decode
            # continues where the prompt left off (stabilizer included).
            return xlstm.prefill(params, batch["tokens"], cfg, rules=rules)
        return f
    if spec.kind == "ssm":
        def f(params, batch, cfg, state, rules=None):
            return ssm.forward(params, batch["tokens"], cfg,
                               rules=rules), state
        return f
    if spec.kind == "nmt":
        def f(params, batch, cfg, state, rules=None):
            # encoder pass + teacher-forced replay of the target prefix:
            # fills (h, c, feed) and the resident attention memory
            # (enc_out / enc_proj / score_bias) so decode continues where
            # the prompt left off.
            return seq2seq.prefill(params, batch, cfg, state, rules=rules)
        return f
    raise ValueError(f"{spec.kind} has no prefill path")


def prefill_batch_specs(spec: ArchSpec, cfg, shape: ShapeSpec):
    d = train_batch_specs(spec, cfg, shape)
    d.pop("labels", None)
    d.pop("tags", None)
    d.pop("tgt_out", None)
    return d


def decode_token_specs(spec: ArchSpec, cfg, shape: ShapeSpec):
    B = shape.global_batch
    if spec.kind == "transformer" and getattr(cfg, "embeds_in", False):
        return _sds((B, 1, cfg.d_model), cfg.compute_dtype)
    return _sds((B, 1), I32)


def decode_state_axes(spec: ArchSpec, cfg):
    """Logical axes for every decode-state leaf (mirror of its structure)."""
    if spec.kind == "transformer":
        kv = ("layer", "batch", "kv_seq", "kv_heads", "head_dim")
        ax = {"k": kv, "v": kv}
        if getattr(cfg, "is_encoder_decoder", False):
            ax["xk"] = kv
            ax["xv"] = kv
        return ax
    if spec.kind == "xlstm":
        ax = {
            "m_C": ("layer", "batch", "heads", "state_k", "state_v"),
            "m_n": ("layer", "batch", "heads", "state_k"),
            "m_m": ("layer", "batch", "heads"),
            "m_conv": ("layer", "batch", "conv", "mlp"),
        }
        if cfg.layer_kinds.count("s"):
            ax.update({
                "s_h": ("layer", "batch", "heads", "head_dim"),
                "s_c": ("layer", "batch", "heads", "head_dim"),
                "s_n": ("layer", "batch", "heads", "head_dim"),
                "s_m": ("layer", "batch", "heads", "head_dim"),
            })
        return ax
    if spec.kind == "ssm":
        ax = {
            "ssm": ("layer", "batch", "heads", "head_dim", "state"),
            "conv": ("layer", "batch", "conv", "mlp"),
        }
        if cfg.shared_attn:
            kv = ("layer", "batch", "kv_seq", "kv_heads", "head_dim")
            ax["attn_k"] = kv
            ax["attn_v"] = kv
        return ax
    if spec.kind == "nmt":
        mem = ("layer", "batch", "kv_seq", "head_dim")
        return {
            "h": ("layer", "batch", "head_dim"),
            "c": ("layer", "batch", "head_dim"),
            "feed": ("layer", "batch", "head_dim"),
            "enc_out": mem,
            "enc_proj": mem,
            "score_bias": ("layer", "batch", "kv_seq"),
        }
    raise ValueError(spec.kind)
