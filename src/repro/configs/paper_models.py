"""The paper's own benchmark configs, selectable via --arch like any arch."""
from repro.configs.base import ArchSpec
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec
from repro.models import lstm_lm, seq2seq, tagger

_LM_SKIPS = {
    "prefill_32k": "word-level LSTM LM; paper shapes are (batch 20, unroll 35)",
    "decode_32k": "see prefill_32k",
    "long_500k": "see prefill_32k",
}


def _st(rate, bs=1):
    return DropoutSpec(rate=rate, block_size=bs)


def _plan(rate, bs=1, sites=("embed", "nr", "rh", "out")):
    return DropoutPlan.case("case3", rate, block_size=bs, sites=sites)


ZAREMBA_MEDIUM = ArchSpec(
    name="zaremba-medium", family="rnn", kind="lstm_lm",
    full=lambda **kw: lstm_lm.zaremba_medium(plan=_plan(0.5), **kw),
    smoke=lambda **kw: lstm_lm.zaremba_medium(
        vocab=128, embed=64, hidden=64,
        plan=DropoutPlan({"embed": _st(0.5), "nr": _st(0.5, 8),
                          "rh": _st(0.5, 8), "out": _st(0.5)}), **kw),
    skip_shapes=_LM_SKIPS)

ZAREMBA_LARGE = ArchSpec(
    name="zaremba-large", family="rnn", kind="lstm_lm",
    full=lambda **kw: lstm_lm.zaremba_large(plan=_plan(0.65), **kw),
    smoke=lambda **kw: lstm_lm.zaremba_large(
        vocab=128, embed=64, hidden=64,
        plan=DropoutPlan({"embed": _st(0.65), "nr": _st(0.65, 8),
                          "rh": _st(0.65, 8), "out": _st(0.65)}), **kw),
    skip_shapes=_LM_SKIPS)

AWD_LSTM = ArchSpec(
    name="awd-lstm", family="rnn", kind="lstm_lm",
    full=lambda **kw: lstm_lm.awd_lstm(**kw),
    smoke=lambda **kw: lstm_lm.awd_lstm(vocab=128, embed=32, hidden=48, **kw),
    skip_shapes=_LM_SKIPS)

LUONG_NMT = ArchSpec(
    name="luong-nmt", family="rnn", kind="nmt",
    full=lambda **kw: seq2seq.NMTConfig(
        plan=_plan(0.3, sites=("nr", "rh", "out")), **kw),
    smoke=lambda **kw: seq2seq.NMTConfig(
        src_vocab=96, tgt_vocab=96, embed=32, hidden=32,
        plan=_plan(0.3, 8, sites=("nr", "rh", "out")), **kw),
    skip_shapes=_LM_SKIPS)

BILSTM_NER = ArchSpec(
    name="bilstm-ner", family="rnn", kind="tagger",
    full=lambda **kw: tagger.TaggerConfig(
        plan=_plan(0.5, sites=("inp", "rh")), **kw),
    smoke=lambda **kw: tagger.TaggerConfig(
        vocab=96, char_vocab=30, hidden=32, num_tags=9,
        word_embed=34, char_filters=30,    # 64-dim concat: 8-block divisible
        plan=_plan(0.5, 8, sites=("inp", "rh")), **kw),
    skip_shapes=_LM_SKIPS)

PAPER_SPECS = [ZAREMBA_MEDIUM, ZAREMBA_LARGE, AWD_LSTM, LUONG_NMT, BILSTM_NER]
