"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000 —
GeGLU, head_dim=256, scaled embeddings [arXiv:2403.08295]."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_SKIP
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec
from repro.models.transformer import TransformerConfig


def full(**kw):
    d = dict(
        name="gemma-2b", num_layers=18, d_model=2048, n_heads=8,
        n_kv_heads=1, head_dim=256, d_ff=16384, vocab=256000,
        mlp="geglu", scale_embed=True, tie_embeddings=True, max_seq=1 << 20,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        kv_repeat=8,                  # MQA -> one kv copy per pair of shards
        q_chunk=1024, kv_chunk=1024,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=128)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


def smoke(**kw):
    d = dict(
        name="gemma-smoke", num_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=16, d_ff=128, vocab=128, mlp="geglu",
        scale_embed=True, tie_embeddings=True, kv_repeat=4,
        q_chunk=8, kv_chunk=8, max_seq=64,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=8)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


SPEC = ArchSpec(
    name="gemma-2b", family="dense", kind="transformer", full=full,
    smoke=smoke, skip_shapes={"long_500k": FULL_ATTN_SKIP})
