"""xlstm-1.3b [ssm]: 48L d_model=2048 4H vocab=50304 — sLSTM + mLSTM blocks
[arXiv:2405.04517]. The arch closest to the paper: sLSTM blocks carry a true
h->h recurrence, so NR+RH+ST structured dropout applies natively."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec
from repro.models.xlstm import XLSTMConfig


def full(**kw):
    d = dict(
        name="xlstm-1.3b", num_layers=48, d_model=2048, n_heads=4,
        vocab=50304, proj_factor=2.0, slstm_every=8, conv_kernel=4,
        chunk=256, param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=128),
                          "rh": DropoutSpec(rate=0.25, block_size=64)}),
    )
    d.update(kw)
    return XLSTMConfig(**d)


def smoke(**kw):
    d = dict(
        name="xlstm-smoke", num_layers=8, d_model=64, n_heads=4, vocab=128,
        proj_factor=2.0, slstm_every=4, chunk=8,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=8),
                          "rh": DropoutSpec(rate=0.5, block_size=1)}),
    )
    d.update(kw)
    return XLSTMConfig(**d)


SPEC = ArchSpec(
    name="xlstm-1.3b", family="ssm", kind="xlstm", full=full, smoke=smoke,
    notes="paper-native RH recurrence (sLSTM); long_500k runs on recurrent state")
