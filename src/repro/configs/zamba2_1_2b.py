"""zamba2-1.2b [hybrid]: 38L d_model=2048 (attn 32H kv=32) d_ff=8192
ssm_state=64 — Mamba2 backbone + weight-shared attention block
[arXiv:2411.15242]."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec
from repro.models.ssm import Mamba2Config


def full(**kw):
    d = dict(
        name="zamba2-1.2b", num_layers=38, d_model=2048, ssm_state=64,
        n_heads=64, expand=2, conv_kernel=4, chunk=256, vocab=32000,
        shared_attn=True, shared_every=6, attn_heads=32, attn_kv_heads=32,
        attn_ff=8192,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=128)}),
    )
    d.update(kw)
    return Mamba2Config(**d)


def smoke(**kw):
    d = dict(
        name="zamba2-smoke", num_layers=8, d_model=64, ssm_state=8,
        n_heads=4, chunk=8, vocab=128, shared_attn=True, shared_every=3,
        attn_heads=4, attn_kv_heads=4, attn_ff=128,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=8)}),
    )
    d.update(kw)
    return Mamba2Config(**d)


SPEC = ArchSpec(
    name="zamba2-1.2b", family="hybrid", kind="ssm", full=full, smoke=smoke,
    notes="RH inapplicable to the linear SSD recurrence (no h->h weight); "
          "NR structured dropout on block inputs; long_500k runs on SSM state")
