"""ArchSpec: uniform handle over every selectable architecture.

Each ``src/repro/configs/<id>.py`` defines SPEC — a factory pair
(full / smoke) plus family metadata and per-shape applicability. The launch
layer (train/serve/dryrun) and the smoke tests consume only this interface.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional



@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio | rnn
    kind: str                    # transformer | xlstm | ssm | lstm_lm | nmt | tagger
    full: Callable[..., object]  # full-size config factory (kw overrides ok)
    smoke: Callable[..., object]  # reduced CPU-runnable config factory
    # Shapes this arch skips entirely, with the reason (DESIGN §Arch-applic.)
    skip_shapes: dict = dataclasses.field(default_factory=dict)
    notes: str = ""

    def applicable(self, shape_name: str) -> Optional[str]:
        """None if runnable; else the documented skip reason."""
        return self.skip_shapes.get(shape_name)


FULL_ATTN_SKIP = ("full quadratic attention; 500k dense-KV decode is out of "
                  "scope for pure full-attention archs (DESIGN §Arch-applicability)")
