"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual [hf:Snowflake/snowflake-arctic-base]."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_SKIP
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec
from repro.models.transformer import MoEConfig, TransformerConfig


def full(**kw):
    d = dict(
        name="arctic-480b", num_layers=35, d_model=7168, n_heads=56,
        n_kv_heads=8, head_dim=128, d_ff=4864, vocab=32000,
        moe=MoEConfig(num_experts=128, top_k=2, dense_ff=4864),
        mlp="swiglu", max_seq=1 << 20,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        kv_repeat=1,   # 56 q / 8 kv = 7 groups: only 1 or 7 divide; 7 would
        q_chunk=1024, kv_chunk=1024,   # 7x the cache — keep GQA, flat-shard

        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=128)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


def smoke(**kw):
    d = dict(
        name="arctic-smoke", num_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab=128,
        moe=MoEConfig(num_experts=8, top_k=2, dense_ff=96),
        q_chunk=8, kv_chunk=8, max_seq=64,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=8)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


SPEC = ArchSpec(
    name="arctic-480b", family="moe", kind="transformer", full=full,
    smoke=smoke, skip_shapes={"long_500k": FULL_ATTN_SKIP},
    notes="dense-residual MoE; largest param count in the pool")
