"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA [arXiv:2401.04088]."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec
from repro.models.transformer import MoEConfig, TransformerConfig


def full(**kw):
    d = dict(
        name="mixtral-8x22b", num_layers=56, d_model=6144, n_heads=48,
        n_kv_heads=8, head_dim=128, d_ff=16384, vocab=32768,
        moe=MoEConfig(num_experts=8, top_k=2), window=4096,
        mlp="swiglu", rope_theta=1e6, max_seq=1 << 20,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        kv_repeat=2,                    # 8 kv heads -> 16 for TP=16
        q_chunk=1024, kv_chunk=1024,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=128)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


def smoke(**kw):
    d = dict(
        name="mixtral-smoke", num_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=128,
        moe=MoEConfig(num_experts=4, top_k=2), window=8,
        q_chunk=8, kv_chunk=8, max_seq=64,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=8)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


SPEC = ArchSpec(
    name="mixtral-8x22b", family="moe", kind="transformer", full=full,
    smoke=smoke,
    notes="SWA bounds the 500k decode window to 4096 -> long_500k runs")
