"""qwen1.5-32b [dense]: 64L d_model=5120 40H (MHA kv=40) d_ff=27392
vocab=152064 — QKV bias [hf:Qwen/Qwen1.5 family]."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, FULL_ATTN_SKIP
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec
from repro.models.transformer import TransformerConfig


def full(**kw):
    d = dict(
        name="qwen1.5-32b", num_layers=64, d_model=5120, n_heads=40,
        n_kv_heads=40, head_dim=128, d_ff=27392, vocab=152064,
        qkv_bias=True, mlp="swiglu", max_seq=1 << 20,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        q_chunk=1024, kv_chunk=1024,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=128)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


def smoke(**kw):
    d = dict(
        name="qwen1.5-smoke", num_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=160, vocab=128, qkv_bias=True,
        q_chunk=8, kv_chunk=8, max_seq=64,
        plan=DropoutPlan({"nr": DropoutSpec(rate=0.25, block_size=8)}),
    )
    d.update(kw)
    return TransformerConfig(**d)


SPEC = ArchSpec(
    name="qwen1.5-32b", family="dense", kind="transformer", full=full,
    smoke=smoke, skip_shapes={"long_500k": FULL_ATTN_SKIP},
    notes="40 heads not divisible by TP=16 -> attention falls back to "
          "replicated head compute (divisibility guard); hillclimb target")
