"""Synthetic data pipeline (no datasets ship offline; see DESIGN §7)."""
from repro.data.synthetic import (lm_stream, nmt_pairs, ner_examples,
                                  token_batches)
from repro.data.pipeline import ShardedBatcher, host_shard
