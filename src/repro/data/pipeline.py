"""Host-sharded batching, token-packed ragged batching + prefetch.

Each host slices the deterministic synthetic stream by
``(host_index, host_count)`` — no data server needed, identical semantics at
1 or 1000 hosts, and a restart resumes from the step counter alone (the
stream is a pure function of (seed, step)) — this is the fault-tolerance
property the checkpoint layer relies on: data state is never checkpointed.

``PackedBatcher`` extends the same contract to ragged corpora: sequences
are bucketed by length caps and packed so every batch holds roughly
``token_budget`` tokens (rows = budget // cap — short-sequence buckets get
proportionally more rows). Combined with the kernels' per-row ``lengths``
carry-freeze (kernels/cell_scan.py) and the masked losses (core/metrics.py)
this recovers the FLOPs a rectangular batcher burns on padding. The
packing plan for an epoch is a pure function of ``(seed, epoch)``, so
restart-at-step resumes bit-identically and every host derives the same
plan locally.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional, Sequence

import numpy as np


def host_shard(global_batch: int, host_index: int, host_count: int):
    """-> (local_batch, offset). Global batch is split evenly across hosts."""
    assert global_batch % host_count == 0, (global_batch, host_count)
    local = global_batch // host_count
    return local, host_index * local


class ShardedBatcher:
    """Deterministic per-step batches: batch_fn(step, host_index) -> pytree.

    ``prefetch`` background-materializes the next batches on a thread so the
    accelerator never waits on numpy generation (CPU-side pipelining).
    """

    def __init__(self, batch_fn: Callable[[int], dict], *,
                 prefetch: int = 2, start_step: int = 0):
        self.batch_fn = batch_fn
        self.step = start_step
        self.prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        if prefetch > 0:
            self._q = queue.Queue(maxsize=prefetch)
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.batch_fn(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._q is None:
            b = self.batch_fn(self.step)
            self.step += 1
            return b
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)


# ---------------------------------------------------------------------------
# token-packed ragged batching
# ---------------------------------------------------------------------------


def bucket_boundaries(max_len: int, n_buckets: int = 4):
    """Geometric length caps ending at ``max_len``: (…, max/4, max/2, max).

    Each halving doubles the bucket's row count at a fixed token budget, so
    per-bucket batch shapes stay static (jit caches one trace per cap)
    while short sequences stop paying for the longest row in the batch.
    """
    caps = set()
    c = int(max_len)
    for _ in range(max(1, n_buckets)):
        caps.add(c)
        c = max(1, c // 2)
    return tuple(sorted(caps))


def pack_plan(lengths, token_budget: int, boundaries: Sequence[int], *,
              seed: int = 0, epoch: int = 0, host_count: int = 1):
    """Deterministic packing plan for one epoch of a ragged corpus.

    Returns a list of ``(cap, row_indices)`` batches where ``row_indices``
    is an int64 array of ``max(1, token_budget // cap)`` corpus indices,
    ``-1`` marking dummy fill rows (length 0 — free under the carry freeze,
    excluded from masked losses). Every sequence appears exactly once per
    epoch; the shuffle and the bucket interleave are pure functions of
    ``(seed, epoch)``; the plan is padded with all-dummy batches so its
    length divides ``host_count`` (all hosts step in lockstep).
    """
    lengths = np.asarray(lengths)
    caps = np.asarray(sorted(int(b) for b in boundaries))
    if lengths.size and int(lengths.max()) > int(caps[-1]):
        raise ValueError(f"max length {int(lengths.max())} exceeds the "
                         f"largest bucket cap {int(caps[-1])}")
    rng = np.random.default_rng([seed, epoch])
    order = rng.permutation(lengths.size)
    which = np.searchsorted(caps, lengths[order])      # smallest cap >= len
    batches = []
    for ci, cap in enumerate(caps):
        rows = max(1, token_budget // int(cap))
        idxs = order[which == ci]
        for j in range(0, len(idxs), rows):
            chunk = np.full(rows, -1, np.int64)
            sl = idxs[j:j + rows]
            chunk[:len(sl)] = sl
            batches.append((int(cap), chunk))
    perm = rng.permutation(len(batches))               # interleave buckets
    batches = [batches[int(k)] for k in perm]
    while len(batches) % host_count:
        cap = int(caps[-1])
        batches.append((cap, np.full(max(1, token_budget // cap), -1,
                                     np.int64)))
    return batches


class PackedBatcher:
    """Deterministic token-packed batches over a padded ragged corpus.

    ``docs`` maps field names to ``(N, max_len, …)`` padded arrays plus
    ``"lengths"`` (N,) int32 (``data.synthetic.lm_ragged_docs`` emits this
    layout). Each step materializes one ``pack_plan`` batch: the bucket's
    rows sliced to its cap (static per-cap shapes), dummy rows all-zero
    with length 0, and the length column emitted under ``length_key`` so
    models opt into the ragged path. Like ``ShardedBatcher``, a batch is a
    pure function of ``(seed, step)`` — resume-from-step needs no data
    state — and hosts shard by taking interleaved plan entries. Feed
    ``batch_fn`` to ``ShardedBatcher`` for background prefetch.
    """

    def __init__(self, docs: dict, token_budget: int, *, seed: int = 0,
                 boundaries: Optional[Sequence[int]] = None,
                 host_index: int = 0, host_count: int = 1,
                 length_key: str = "lengths"):
        self.lengths = np.asarray(docs["lengths"], np.int32)
        self.fields = {k: np.asarray(v) for k, v in docs.items()
                       if k != "lengths"}
        self.token_budget = int(token_budget)
        if boundaries is None:
            boundaries = bucket_boundaries(
                int(self.lengths.max()) if self.lengths.size else 1)
        self.boundaries = tuple(sorted(int(b) for b in boundaries))
        self.seed = seed
        self.host_index = host_index
        self.host_count = host_count
        self.length_key = length_key
        self._plan_cache: dict = {}

    def _plan(self, epoch: int):
        if epoch not in self._plan_cache:
            self._plan_cache.clear()               # keep one epoch resident
            self._plan_cache[epoch] = pack_plan(
                self.lengths, self.token_budget, self.boundaries,
                seed=self.seed, epoch=epoch, host_count=self.host_count)
        return self._plan_cache[epoch]

    @property
    def steps_per_epoch(self) -> int:
        return len(self._plan(0)) // self.host_count

    def batch_fn(self, step: int) -> dict:
        epoch, idx = divmod(step, self.steps_per_epoch)
        cap, rows = self._plan(epoch)[idx * self.host_count
                                      + self.host_index]
        real = rows >= 0
        batch = {}
        for k, arr in self.fields.items():
            out = np.zeros((len(rows), cap) + arr.shape[2:], arr.dtype)
            out[real] = arr[rows[real], :cap]
            batch[k] = out
        batch[self.length_key] = np.where(
            real, self.lengths[np.maximum(rows, 0)], 0).astype(np.int32)
        return batch

    def __iter__(self) -> Iterator:
        step = 0
        while True:
            yield self.batch_fn(step)
            step += 1
