"""Host-sharded batching + background prefetch.

Each host slices the deterministic synthetic stream by
``(host_index, host_count)`` — no data server needed, identical semantics at
1 or 1000 hosts, and a restart resumes from the step counter alone (the
stream is a pure function of (seed, step)) — this is the fault-tolerance
property the checkpoint layer relies on: data state is never checkpointed.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional


def host_shard(global_batch: int, host_index: int, host_count: int):
    """-> (local_batch, offset). Global batch is split evenly across hosts."""
    assert global_batch % host_count == 0, (global_batch, host_count)
    local = global_batch // host_count
    return local, host_index * local


class ShardedBatcher:
    """Deterministic per-step batches: batch_fn(step, host_index) -> pytree.

    ``prefetch`` background-materializes the next batches on a thread so the
    accelerator never waits on numpy generation (CPU-side pipelining).
    """

    def __init__(self, batch_fn: Callable[[int], dict], *,
                 prefetch: int = 2, start_step: int = 0):
        self.batch_fn = batch_fn
        self.step = start_step
        self.prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        if prefetch > 0:
            self._q = queue.Queue(maxsize=prefetch)
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.batch_fn(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._q is None:
            b = self.batch_fn(self.step)
            self.step += 1
            return b
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)
