"""Deterministic synthetic corpora with matched statistics.

No real datasets ship in this container, so the paper's *relative* claims
(structured vs random dropout at the same rate, same data) are validated on
synthetic streams whose vocabulary sizes and sequence statistics match the
originals:

  * lm_stream   — Zipfian token stream with a 2nd-order Markov structure so
                  an LSTM has something learnable (PTB-like, vocab 10k).
  * nmt_pairs   — copy+local-permute+noise translation pairs (learnable
                  monotone alignment, distinct src/tgt vocabs).
  * ner_examples— tag-pattern sequences: entity spans are marked by
                  trigger-word classes so BiLSTM+CRF can learn transitions.

All generators are pure numpy with explicit seeds — reproducible across
hosts, trivially shardable by slicing the stream.
"""
from __future__ import annotations

import numpy as np


def lm_stream(vocab: int, length: int, *, seed: int = 0,
              zipf_a: float = 1.2) -> np.ndarray:
    """Zipf-distributed tokens with Markov back-off (learnable bigrams)."""
    rng = np.random.default_rng(seed)
    base = rng.zipf(zipf_a, size=length).astype(np.int64)
    base = (base - 1) % vocab
    # 2nd-order structure: with p=.55 the next token is a deterministic
    # function of the previous two -> a model that learns context wins.
    out = base.copy()
    coin = rng.random(length)
    for t in range(2, length):
        if coin[t] < 0.55:
            out[t] = (out[t - 1] * 31 + out[t - 2] * 17 + 7) % vocab
    return out.astype(np.int32)


def token_batches(stream: np.ndarray, batch: int, seq: int):
    """Contiguous BPTT batching (Zaremba-style): yields (tokens, labels)."""
    n = len(stream) // batch
    data = stream[:n * batch].reshape(batch, n)
    for i in range(0, n - seq - 1, seq):
        yield data[:, i:i + seq], data[:, i + 1:i + seq + 1]


def lm_ragged_docs(n: int, vocab: int, max_len: int, *, seed: int = 0,
                   skew: float = 1.0):
    """Ragged LM corpus: ``n`` documents with lognormal-skewed lengths.

    Returns ``{"tokens" (n, max_len) int32 zero-padded, "labels" idem,
    "lengths" (n,) int32}``. The length distribution is the production-
    trace shape (many short requests, a long tail near max_len) that makes
    rectangular padding wasteful — feed it to ``pipeline.PackedBatcher``
    to recover the padding FLOPs. ``skew`` is the lognormal sigma; larger
    = more short docs relative to the max.
    """
    rng = np.random.default_rng(seed)
    raw = rng.lognormal(mean=np.log(max_len) - 1.5 * skew, sigma=skew,
                        size=n)
    lengths = np.clip(np.rint(raw).astype(np.int64), 2, max_len).astype(
        np.int32)
    stream = lm_stream(vocab, int(lengths.sum()) + 1, seed=seed + 1)
    tokens = np.zeros((n, max_len), np.int32)
    labels = np.zeros((n, max_len), np.int32)
    pos = 0
    for i, L in enumerate(lengths):
        tokens[i, :L] = stream[pos:pos + L]
        labels[i, :L - 1] = stream[pos + 1:pos + L]
        labels[i, L - 1] = stream[(pos + L) % len(stream)]
        pos += L
    return {"tokens": tokens, "labels": labels, "lengths": lengths}


def nmt_pairs(n: int, src_vocab: int, tgt_vocab: int, max_len: int = 24,
              *, seed: int = 0):
    """Learnable toy translation: tgt = affine-remapped src with local swaps.

    Returns dict of padded arrays: src, src_mask, tgt_in, tgt_out, tgt_mask.
    Token 0 = pad, 1 = BOS, 2 = EOS.
    """
    rng = np.random.default_rng(seed)
    src = np.zeros((n, max_len), np.int32)
    tgt_in = np.zeros((n, max_len), np.int32)
    tgt_out = np.zeros((n, max_len), np.int32)
    src_mask = np.zeros((n, max_len), bool)
    tgt_mask = np.zeros((n, max_len), bool)
    for i in range(n):
        L = rng.integers(6, max_len - 1)
        s = rng.integers(3, src_vocab, size=L)
        t = (s * 7 + 3) % (tgt_vocab - 3) + 3
        # local permutation noise: swap ~20% of adjacent pairs
        for j in range(0, L - 1, 2):
            if rng.random() < 0.2:
                t[j], t[j + 1] = t[j + 1], t[j]
        src[i, :L] = s
        src_mask[i, :L] = True
        tgt_in[i, 0] = 1
        tgt_in[i, 1:L + 1] = t[:max_len - 1][:L]
        tgt_out[i, :L] = t[:max_len][:L]
        tgt_out[i, L] = 2 if L < max_len else t[-1]
        tgt_mask[i, :min(L + 1, max_len)] = True
    return {"src": src, "src_mask": src_mask, "tgt_in": tgt_in,
            "tgt_out": tgt_out, "tgt_mask": tgt_mask}


def ner_examples(n: int, vocab: int, char_vocab: int, num_tags: int = 9,
                 seq: int = 24, word_len: int = 12, *, seed: int = 0):
    """Tag-pattern NER: trigger classes deterministically open entity spans.

    BIO-style tags over (num_tags-1)//2 entity types; words in an entity
    span come from a type-specific vocabulary band.
    """
    rng = np.random.default_rng(seed)
    n_types = (num_tags - 1) // 2
    words = np.zeros((n, seq), np.int32)
    chars = np.zeros((n, seq, word_len), np.int32)
    tags = np.zeros((n, seq), np.int32)
    band = (vocab - 10) // (n_types + 1)
    for i in range(n):
        t = 0
        while t < seq:
            if rng.random() < 0.25 and t < seq - 2:
                typ = rng.integers(0, n_types)
                span = rng.integers(1, 4)
                lo = 10 + (typ + 1) * band
                for j in range(min(span, seq - t)):
                    words[i, t] = rng.integers(lo, min(lo + band, vocab))
                    tags[i, t] = 1 + 2 * typ + (0 if j == 0 else 1)  # B-x/I-x
                    t += 1
            else:
                words[i, t] = rng.integers(10, 10 + band)
                tags[i, t] = 0
                t += 1
        # char ids derived from the word id (consistent morphology)
        for t in range(seq):
            w = int(words[i, t])
            for c in range(word_len):
                chars[i, t, c] = (w * (c + 3) + c) % (char_vocab - 1) + 1
    mask = np.ones((n, seq), bool)
    return {"words": words, "chars": chars, "tags": tags, "mask": mask}
