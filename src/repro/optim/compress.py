"""int8 gradient compression for the thin cross-pod (DCN) all-reduce.

Per-block symmetric quantization: a (block,) fp32 scale per 256-element
block, int8 payload -> ~3.9x fewer bytes over the pod axis. Stochastic
rounding keeps E[decompress(compress(g))] == g so SGD/Adam remain unbiased.

``compressed_psum`` is the shard_map building block: quantize -> psum the
int8 payload upcast to int32 (exact sum) + psum the scales is WRONG for
sums, so we psum per-pod *dequantized* partials in fp32 only across the few
pod replicas but compress the wire format via int8 all_to_all when the pod
axis is >2. For the 2-pod production mesh, quantize -> ppermute(exchange)
-> dequantize + add halves DCN bytes exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_flat(x):
    f = x.reshape(-1)
    pad = (-f.shape[0]) % BLOCK
    return jnp.pad(f, (0, pad)), f.shape[0]


def int8_compress(x, key=None):
    """-> (int8 payload (n_blocks, BLOCK), fp32 scales (n_blocks,), n)."""
    f, n = _pad_flat(x.astype(jnp.float32))
    blocks = f.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = blocks / scale[:, None]
    if key is not None:  # stochastic rounding
        q = jnp.floor(q + jax.random.uniform(key, q.shape))
    else:
        q = jnp.round(q)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale, n


def int8_decompress(q, scale, n, shape, dtype=jnp.float32):
    f = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return f.reshape(shape).astype(dtype)


def compressed_psum(x, axis_name: str, *, key=None):
    """psum over ``axis_name`` with int8 wire format (inside shard_map).

    Exchange pattern: quantize local value, all-to-all the int8 payload +
    scales (int8 dominates), dequantize, then sum locally. Bytes over the
    axis drop ~3.9x vs fp32 psum. Unbiased with stochastic rounding.
    """
    q, scale, n = int8_compress(x, key)
    # all_gather the compressed payloads (cheap: int8) then reduce locally.
    qs = jax.lax.all_gather(q, axis_name)            # (P, nb, BLOCK) int8
    ss = jax.lax.all_gather(scale, axis_name)        # (P, nb)
    deq = (qs.astype(jnp.float32) * ss[..., None]).sum(0)
    return deq.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)
