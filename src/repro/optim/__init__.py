"""Optimizers (pure-JAX, optax-style API but dependency-free)."""
from repro.optim.optimizers import (sgd, adamw, nt_asgd, clip_by_global_norm,
                                    chain, OptState, apply_updates)
from repro.optim.schedules import (constant, step_decay, cosine,
                                   linear_warmup_cosine)
from repro.optim.accumulate import gradient_accumulation
from repro.optim.compress import int8_compress, int8_decompress, compressed_psum
