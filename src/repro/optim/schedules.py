"""Learning-rate schedules (callable(step) -> float)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: lr


def step_decay(lr: float, decay: float, every: int, start: int = 0):
    """Zaremba'14: constant for `start` epochs then decay per epoch."""
    def f(step):
        k = jnp.maximum(step - start, 0) // every
        return lr * decay ** k
    return f


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step / total_steps, 0.0, 1.0)
        return lr * (final_frac + (1 - final_frac)
                     * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return f


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine(lr, total_steps - warmup, final_frac)

    def f(step):
        return jnp.where(step < warmup, lr * step / jnp.maximum(warmup, 1),
                         cos(step - warmup))
    return f
