"""Optimizers as (init, update) pairs over param pytrees.

``update(grads, state, params) -> (updates, state)`` followed by
``apply_updates``; mirrors the optax contract so swapping in optax later is
mechanical. Moments are kept in fp32 regardless of param dtype (bf16 params
+ fp32 m/v is the deployment configuration costed in EXPERIMENTS §Dry-run).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


OptState = Any


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p + u.astype(p.dtype)).astype(p.dtype), params, updates)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        g = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
        return jax.tree.map(lambda x: x * scale, grads), state

    return Optimizer(init, update)


def sgd(lr) -> Optimizer:
    """lr: float or callable(step) -> float. State = step counter."""
    def init(params):
        return jnp.zeros((), jnp.int32)

    def update(grads, step, params=None):
        rate = lr(step) if callable(lr) else lr
        return jax.tree.map(lambda g: -rate * g.astype(jnp.float32), grads), \
            step + 1

    return Optimizer(init, update)


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        rate = lr(step) if callable(lr) else lr
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v
                         + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        mh = jax.tree.map(lambda m: m / (1 - b1 ** step), m)
        vh = jax.tree.map(lambda v: v / (1 - b2 ** step), v)
        upd = jax.tree.map(
            lambda mh, vh, p: -rate * (mh / (jnp.sqrt(vh) + eps)
                                       + weight_decay * p.astype(jnp.float32)),
            mh, vh, params)
        return upd, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def nt_asgd(lr, trigger_window: int = 5) -> Optimizer:
    """Non-monotonically-triggered ASGD (AWD-LSTM's optimizer).

    SGD until validation stops improving (caller flips ``state["avg_on"]``
    via ``trigger_averaging``), then iterate averaging of parameters.
    The averaged copy lives in the state; ``averaged_params`` reads it out.
    """
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "avg_on": jnp.zeros((), jnp.bool_),
                "avg_start": jnp.zeros((), jnp.int32),
                "avg": jax.tree.map(lambda p: p.astype(jnp.float32), params)}

    def update(grads, state, params):
        step = state["step"] + 1
        rate = lr(step) if callable(lr) else lr
        upd = jax.tree.map(lambda g: -rate * g.astype(jnp.float32), grads)
        # running average of the *post-update* params when triggered
        k = jnp.maximum(step - state["avg_start"], 1).astype(jnp.float32)
        new_avg = jax.tree.map(
            lambda a, p, u: jnp.where(
                state["avg_on"],
                a + ((p.astype(jnp.float32) + u) - a) / k,
                p.astype(jnp.float32) + u),
            state["avg"], params, upd)
        return upd, {**state, "step": step, "avg": new_avg}

    return Optimizer(init, update)


def trigger_averaging(state):
    return {**state, "avg_on": jnp.ones((), jnp.bool_),
            "avg_start": state["step"]}


def averaged_params(state, params):
    return jax.tree.map(lambda a, p: a.astype(p.dtype), state["avg"], params)


def chain(*opts: Optimizer) -> Optimizer:
    """Compose transforms left-to-right (e.g. clip -> adamw)."""
    def init(params):
        return tuple(o.init(params) for o in opts)

    def update(grads, states, params):
        new_states = []
        for o, s in zip(opts, states):
            grads, s = o.update(grads, s, params)
            new_states.append(s)
        return grads, tuple(new_states)

    return Optimizer(init, update)
