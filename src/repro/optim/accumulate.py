"""Gradient accumulation: microbatch a step via lax.scan (compact HLO).

Splits the leading batch dim into ``n_micro`` slices and averages grads.
Memory drops ~n_micro-fold for activations; the optimizer update runs once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gradient_accumulation(loss_fn, n_micro: int):
    """loss_fn(params, batch, **kw) -> scalar. Returns (loss, grads) fn."""
    if n_micro <= 1:
        def simple(params, batch, **kw):
            return jax.value_and_grad(
                lambda p: loss_fn(p, batch, **kw))(params)
        return simple

    def accumulated(params, batch, **kw):
        def reshape(x):
            b = x.shape[0]
            assert b % n_micro == 0, (b, n_micro)
            return x.reshape(n_micro, b // n_micro, *x.shape[1:])

        micro = jax.tree.map(reshape, batch)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, mb, **kw))(params)
            grad_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_micro,
                grad_acc, grads)
            return (loss_acc + loss / n_micro, grad_acc), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero_g), micro)
        return loss, grads

    return accumulated
