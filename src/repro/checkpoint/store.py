"""Sharded, topology-agnostic checkpoints with crash-safe manifests.

Layout (one dir per step):
    ckpt_dir/step_000123/
        shard_00000_of_00004.npz    # this host's param/opt leaves
        MANIFEST.json               # written LAST -> atomic commit marker

Fault-tolerance properties:
  * A checkpoint without MANIFEST.json is incomplete (crashed mid-write) and
    is ignored + garbage-collected on the next save.
  * Leaves are saved with their *logical* tree paths, not device layouts, so
    a restart on a different mesh/host count resharding is just the usual
    device_put against the new NamedShardings (elastic re-mesh).
  * ``PreemptionHook`` converts SIGTERM into a final synchronous save.
  * Data pipeline state is NOT stored — batches are a pure function of
    (seed, step), so restore = (params, opt_state, step).
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    """-> (ordered {path_key: leaf} in tree order, treedef, ordered keys)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {}
    order = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        keyed[key] = leaf
        order.append(key)
    return keyed, treedef, order


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    host_index: int = 0, host_count: int = 1,
                    keep: int = 3, meta: Any = None) -> str:
    """Save this host's shard of ``tree``. Leaves are round-robin assigned to
    hosts by index so every leaf is stored exactly once across the fleet.
    ``meta`` (JSON-serializable, e.g. the DropoutPlan dict of the run) is
    recorded verbatim in the manifest."""
    keyed, _, _ = _flatten(tree)
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    os.makedirs(step_dir, exist_ok=True)

    def _np(v):
        a = np.asarray(v)
        if a.dtype.kind not in "biufc":      # bf16/f8: store as f32 (exact)
            a = a.astype(np.float32)
        return a

    mine = {k: _np(v) for i, (k, v) in enumerate(sorted(keyed.items()))
            if i % host_count == host_index}
    shard = os.path.join(
        step_dir, f"shard_{host_index:05d}_of_{host_count:05d}.npz")
    tmp = shard + ".tmp.npz"
    np.savez(tmp, **{k: v for k, v in mine.items()})
    os.replace(tmp, shard)

    if host_index == 0:
        manifest = {
            "step": step,
            "host_count": host_count,
            "keys": sorted(keyed.keys()),
            "shapes": {k: list(np.shape(v)) for k, v in keyed.items()},
        }
        if meta is not None:
            manifest["meta"] = meta
        mpath = os.path.join(step_dir, "MANIFEST.json")
        with tempfile.NamedTemporaryFile("w", dir=step_dir, delete=False) as f:
            json.dump(manifest, f)
            tmpname = f.name
        os.replace(tmpname, mpath)                   # atomic commit
        _gc(ckpt_dir, keep)
    return step_dir


def _complete_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "MANIFEST.json")):
            steps.append(int(d.split("_")[1]))
    return sorted(steps)


def _gc(ckpt_dir: str, keep: int):
    steps = _complete_steps(ckpt_dir)
    # also remove incomplete dirs older than the newest complete one
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_"):
            continue
        s = int(d.split("_")[1])
        complete = s in steps
        stale_incomplete = (not complete and steps and s < steps[-1])
        evicted = complete and len(steps) > keep and s in steps[:-keep]
        if stale_incomplete or evicted:
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _complete_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like: Any, *,
                       step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``; reshard via
    ``shardings`` (same pytree shape) when provided — elastic re-mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(step_dir, "MANIFEST.json")) as f:
        manifest = json.load(f)

    data = {}
    for fname in os.listdir(step_dir):
        if fname.startswith("shard_") and fname.endswith(".npz"):
            with np.load(os.path.join(step_dir, fname)) as z:
                for k in z.files:
                    data[k] = z[k]
    missing = set(manifest["keys"]) - set(data)
    if missing:
        raise IOError(f"checkpoint step {step} missing leaves: "
                      f"{sorted(missing)[:5]}...")

    keyed, treedef, order = _flatten(tree_like)
    leaves = []
    for k in order:                               # treedef (tree) order
        ref = keyed[k]
        v = np.asarray(data[k])
        ref_dtype = getattr(ref, "dtype", v.dtype)
        if v.dtype != ref_dtype:                  # bf16 etc.: cast via jnp
            import jax.numpy as jnp
            v = jnp.asarray(v).astype(ref_dtype)
        leaves.append(v)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda x, s: jax.device_put(x, s), restored, shardings)
    return restored, step


class PreemptionHook:
    """SIGTERM -> request a final checkpoint at the next step boundary."""

    def __init__(self):
        self.requested = threading.Event()
        self._prev = signal.signal(signal.SIGTERM, self._handler)

    def _handler(self, signum, frame):
        self.requested.set()

    @property
    def should_save(self) -> bool:
        return self.requested.is_set()

    def restore(self):
        signal.signal(signal.SIGTERM, self._prev)
