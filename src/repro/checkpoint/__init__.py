"""Fault-tolerant checkpointing: sharded npz + manifest + auto-resume."""
from repro.checkpoint.store import (save_checkpoint, restore_checkpoint,
                                    latest_step, PreemptionHook)
