"""Logical-axis sharding: the bridge between model code and the mesh.

Model init functions tag every parameter leaf with *logical* axis names
(``tag(value, "embed", "mlp")``). A ``ShardingRules`` table maps logical
names to physical mesh axes (or None = replicated). This keeps model code
mesh-agnostic: the same model runs on (16,16) ``("data","model")``,
(2,16,16) ``("pod","data","model")``, or a 1-device CPU mesh, purely by
swapping rules — the MaxText/Flax "logical axis" pattern, dependency-free.

Physical mapping (defaults):
  batch    -> ("pod", "data")   data parallel over pods x pod-local DP
  embed    -> "data"            FSDP: weights sharded over DP, gathered on use
                                (replicated across pods: cross-DCN ZeRO-3 is
                                not worth the DCN all-gathers)
  heads/kv_heads/mlp/vocab/expert -> "model"   tensor / expert parallelism
  seq      -> None (or "model" for context-parallel attention configs)

Rules are plain dicts so per-arch overrides are one-line diffs; unknown
logical names map to None (replicated) loudly via ``strict``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[str, Sequence[str], None]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    """A parameter value tagged with logical axis names (one per dim)."""
    value: Any
    axes: tuple

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def tag(value, *axes) -> Param:
    if hasattr(value, "ndim") and len(axes) != value.ndim:
        raise ValueError(f"axes {axes} do not match value ndim {value.ndim}")
    return Param(value, tuple(axes))


def _is_param(x):
    return isinstance(x, Param)


def unzip(tree):
    """Split a Param-tagged tree into (values_tree, axes_tree).

    Untagged leaves (models without sharding annotations, e.g. the paper's
    RNN families) pass through with all-None axes, i.e. replicated."""
    values = jax.tree.map(lambda p: p.value if _is_param(p) else p, tree,
                          is_leaf=_is_param)
    axes = jax.tree.map(
        lambda p: p.axes if _is_param(p)
        else (None,) * getattr(p, "ndim", 0), tree, is_leaf=_is_param)
    return values, axes


def strip(tree):
    """Values only (CPU tests / places that don't care about sharding)."""
    return jax.tree.map(lambda p: p.value if _is_param(p) else p, tree,
                        is_leaf=_is_param)


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

DEFAULT_RULES: dict = {
    "batch": ("pod", "data"),
    "seq": None,            # "model" enables context-parallel attention
    "kv_seq": None,
    "embed": "data",        # FSDP axis for weights
    "embed_act": None,      # activation d_model dim
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": None,         # "model" enables expert parallelism
    "expert_mlp": "model",
    "layer": None,
    "state": None,
    "conv": None,
    "norm": None,
    "cap": None,            # MoE capacity dim
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Mapping[str, Axes]
    mesh_axes: tuple = ("data", "model")

    def resolve(self, name: Optional[str]) -> Axes:
        if name is None:
            return None
        ax = self.rules.get(name, None)
        # Drop mesh axes the current mesh doesn't have (e.g. "pod" on 2D mesh).
        if isinstance(ax, str):
            return ax if ax in self.mesh_axes else None
        if isinstance(ax, (tuple, list)):
            kept = tuple(a for a in ax if a in self.mesh_axes)
            return kept if kept else None
        return None

    def with_(self, **overrides) -> "ShardingRules":
        return ShardingRules({**self.rules, **overrides}, self.mesh_axes)


def rules_for_mesh(mesh: Mesh, overrides: Optional[dict] = None) -> ShardingRules:
    r = dict(DEFAULT_RULES)
    if overrides:
        r.update(overrides)
    return ShardingRules(r, tuple(mesh.axis_names))


def logical_to_pspec(axes: Sequence[Optional[str]], rules: ShardingRules,
                     shape: Optional[Sequence[int]] = None,
                     mesh: Optional[Mesh] = None) -> P:
    """Map logical axis names to a PartitionSpec, dropping non-divisible axes.

    Divisibility guard: a logical axis whose dim isn't divisible by the mesh
    axis size falls back to replication (e.g. 40 heads on a 16-way "model"
    axis). This makes every config lower cleanly; the roofline then exposes
    the cost of replication, which is the honest signal to hillclimb on.
    """
    parts = []
    used: set = set()
    for i, name in enumerate(axes):
        ax = rules.resolve(name)
        if ax is not None and shape is not None and mesh is not None:
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            if shape[i] % size != 0:
                ax = None
        # a mesh axis may appear at most once per spec: first dim wins
        # (e.g. ("mlp","heads") both -> "model" on fused in/out projections)
        if ax is not None:
            flat = ax if isinstance(ax, tuple) else (ax,)
            if any(a in used for a in flat):
                ax = None
            else:
                used.update(flat)
        parts.append(ax)
    # PartitionSpec with trailing Nones trimmed is equivalent; keep full rank.
    return P(*parts)


def make_shardings(axes_tree, rules: ShardingRules, mesh: Mesh,
                   shapes_tree=None):
    """NamedSharding tree from a logical-axes tree (+ optional shapes tree)."""
    def is_axes(x):
        return isinstance(x, tuple) and all(isinstance(a, (str, type(None)))
                                            for a in x)

    if shapes_tree is None:
        return jax.tree.map(
            lambda ax: NamedSharding(mesh, logical_to_pspec(ax, rules)),
            axes_tree, is_leaf=is_axes)
    return jax.tree.map(
        lambda ax, s: NamedSharding(
            mesh, logical_to_pspec(ax, rules, getattr(s, "shape", None), mesh)),
        axes_tree, shapes_tree, is_leaf=is_axes)


def shard_put(tree, axes_tree, rules: ShardingRules, mesh: Mesh):
    """device_put a VALUE tree onto the mesh by its logical-axes tree
    (divisibility-guarded: non-divisible dims replicate). Used to place
    serving decode state — batch/slots over ("pod","data"), kv-heads over
    "model" — without the values ever living unsharded on one device."""
    return jax.device_put(tree, make_shardings(axes_tree, rules, mesh, tree))


def shard_act(x: jax.Array, axes: Sequence[Optional[str]],
              rules: Optional[ShardingRules]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op when rules is None
    (CPU tests) or when we're not inside a mesh context."""
    if rules is None:
        return x
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            return x
    except Exception:
        return x
    spec = logical_to_pspec(axes, rules, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
