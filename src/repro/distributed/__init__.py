"""Distribution: logical-axis sharding rules, collective helpers."""
from repro.distributed.sharding import (Param, tag, unzip, strip, logical_to_pspec,
                                        make_shardings, shard_act, ShardingRules,
                                        DEFAULT_RULES)
