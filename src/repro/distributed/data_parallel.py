"""Data-parallel shard_map wrapper for the recurrent training engines.

``sharded_value_and_grad`` puts a per-shard loss under ``shard_map`` on the
batch axes of a ("data", "model") / ("pod", "data", "model") mesh and
combines shards EXACTLY: every supported loss is a weighted mean
``sum(elems * m) / max(sum(m), 1)`` (configs/adapters.py ``loss_weight``),
so with per-shard weight ``w_i`` and local loss ``l_i``

    global_loss  = psum(l_i * w_i) / max(psum(w_i), 1)
    global_grads = psum(grad(l_i * w_i)) / max(psum(w_i), 1)

reproduces the single-device loss and gradients bit-for-bit in exact
arithmetic — ragged batches and all-pad shards included (an all-dummy
shard has ``l_i = 0`` from the clamped local denominator and ``w_i = 0``,
so its contribution ``l_i * w_i = 0`` equals its true masked sum). The
weights carry no parameter dependence, so the product rule adds nothing.

What replicates vs shards (the MaskSchedule shard-safety contract):

  * params + the recurrent weight U: replicated (``P()`` in_specs) — every
    shard runs the full scan on its batch rows; grads psum across shards.
  * batch leaves: dim 0 sharded over the batch axes ("pod", "data").
  * structured keep-block tables (case3/case4): batch-independent by
    construction — each shard resamples the identical table from the same
    site key (free replication, no communication).
  * dense per-row bitmasks (case1/case2): the local loss binds the plan
    with a ``BatchShard`` so each shard samples the GLOBAL mask and keeps
    its contiguous row block — bit-identical rows to the unsharded run
    (core/dropout_plan.py, "Batch sharding").

Non-divisible batches raise ``ValueError`` here, at the entry, with the
offending leaves named — not as an opaque XLA reshape error mid-lowering.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.dropout_plan import BatchShard

# Mesh axes a batch dim shards over, in linearization order (the same
# physical mapping distributed/sharding.py DEFAULT_RULES gives "batch").
BATCH_AXES = ("pod", "data")


def batch_axes(mesh: Mesh) -> tuple:
    """The subset of BATCH_AXES this mesh actually has, in order."""
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)


def shard_count(mesh: Mesh, axes: Optional[Sequence[str]] = None) -> int:
    """Static number of batch shards (product of the batch-axis sizes)."""
    axes = batch_axes(mesh) if axes is None else tuple(axes)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def shard_index(mesh: Mesh, axes: Sequence[str]):
    """This shard's linearized batch-axis index (traced int32; call only
    inside shard_map). Row-major over ``axes``, matching how shard_map
    assigns dim-0 blocks to ``P(axes)``."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def check_batch_divisible(batch: dict, n_shards: int) -> None:
    """Raise a clear ValueError when any batch leaf's dim 0 can't split
    into ``n_shards`` equal blocks (the failure would otherwise surface as
    an opaque XLA reshape error from inside shard_map lowering)."""
    if n_shards <= 1:
        return
    bad = {k: tuple(v.shape) for k, v in batch.items()
           if getattr(v, "ndim", 0) >= 1 and v.shape[0] % n_shards != 0}
    if bad:
        raise ValueError(
            f"batch dim 0 must be divisible by the {n_shards} batch shards "
            f"of the mesh; offending leaves: {bad}. Pad or rebatch (see "
            f"docs/distributed.md).")


def batch_pspecs(batch: dict, axes: Sequence[str]) -> dict:
    """PartitionSpecs sharding every array leaf's dim 0 over ``axes``."""
    ax = tuple(axes)
    return {k: P(ax) if getattr(v, "ndim", 0) >= 1 else P()
            for k, v in batch.items()}


def sharded_value_and_grad(loss_fn: Callable, weight_fn: Callable,
                           mesh: Mesh, *,
                           axes: Optional[Sequence[str]] = None) -> Callable:
    """Build ``(params, batch, step, key) -> (loss, grads)`` under shard_map.

    ``loss_fn(params, local_batch, step, key, shard)`` returns the LOCAL
    weighted-mean loss (a model loss_fn with cfg/rules closed over, the
    ``shard`` kwarg threading the BatchShard into ``DropoutPlan.bind``).
    ``weight_fn(local_batch)`` returns its weight (the un-clamped local
    denominator). Params arrive replicated; batch leaves shard dim 0.
    """
    axes = batch_axes(mesh) if axes is None else tuple(axes)
    n = shard_count(mesh, axes)

    def local(params, batch, step, key):
        shard = BatchShard(index=shard_index(mesh, axes), count=n)
        w = jnp.float32(weight_fn(batch))
        lsum, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, step, key, shard)
            * w.astype(jnp.float32))(params)
        wsum = jax.lax.psum(w, axes) if axes else w
        denom = jnp.maximum(wsum, 1.0)
        if axes:
            lsum = jax.lax.psum(lsum, axes)
            grads = jax.tree.map(lambda g: jax.lax.psum(g, axes), grads)
        loss = lsum / denom
        grads = jax.tree.map(lambda g: (g / denom).astype(g.dtype), grads)
        return loss, grads

    def vag(params, batch, step, key):
        check_batch_divisible(batch, n)
        f = shard_map(local, mesh=mesh,
                      in_specs=(P(), batch_pspecs(batch, axes), P(), P()),
                      out_specs=(P(), P()),
                      check_rep=False)
        return f(params, batch, step, key)

    return vag
