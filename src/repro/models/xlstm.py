"""xLSTM (sLSTM + mLSTM blocks) — arXiv:2405.04517, TPU-native forms.

The assigned xlstm-1.3b is the closest architecture to the paper's own scope:
sLSTM blocks have a true hidden-to-hidden recurrence, so the paper's
**RH structured dropout** applies natively (the recurrent matmul consumes
``h_{t-1}`` through ``sdrop_matmul``); mLSTM has a linear (matrix-memory)
recurrence with no h-to-h weight, so only the NR direction applies there.

Forms chosen for TPU:
  * mLSTM — *chunkwise-parallel* linear attention with exponential-gate
    log-space stabilization (the sequential form would serialize T steps of
    rank-1 updates; chunkwise turns it into MXU matmuls, ~c× fewer FLOPs).
  * sLSTM — time scan (inherently sequential, as in the paper), with
    block-diagonal per-head recurrent weights. The RH mask is shared across
    heads so compacted recurrent matmul shapes stay static.

Block layout (1.3b): every ``slstm_every``-th block is sLSTM, rest mLSTM,
stacked-weight scans per group for O(1) HLO in depth.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import masks as _masks
from repro.core import metrics
from repro.core import sparse_matmul as sm
from repro.core.dropout_plan import DropoutPlan
from repro.distributed.sharding import tag, shard_act
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    name: str = "xlstm"
    num_layers: int = 8
    d_model: int = 128
    n_heads: int = 4
    vocab: int = 256
    proj_factor: float = 2.0      # mLSTM inner = pf * d_model
    slstm_every: int = 8          # every k-th block is sLSTM
    conv_kernel: int = 4
    chunk: int = 64               # mLSTM chunk length
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    loss_chunks: int = 8
    remat: str = "full"
    # dropout pattern over named sites: "nr" (block input projections, time
    # axis = layer index) and "rh" (sLSTM recurrent direction, time axis =
    # sequence step)
    plan: DropoutPlan = DropoutPlan()
    # recurrent engine for the sLSTM time scan: "scheduled" samples the RH
    # mask schedule pre-scan (rows threaded as scan xs — no in-scan PRNG);
    # "stepwise" draws ctx.state per step. The NR projections are already
    # time-batched outside the scan in every engine. "fused" shares
    # scheduled's Phase A and runs Phase B — the whole T-step sLSTM
    # recurrence (exponential gating, (c, n, m) cell/normalizer/stabilizer
    # carries, per-head block-diagonal R) — as ONE kernels/slstm_scan.py
    # call with R resident across steps, compact per-step RH gathers off
    # the schedule ids table, and a fused reverse-time custom_vjp backward.
    engine: str = "scheduled"
    # §Perf (EXPERIMENTS.md xlstm iter 3): keep the sLSTM h carry replicated
    # so the per-step RH compaction gather stays local. Off by default =
    # the paper-faithful baseline recorded in the §Roofline table.
    pin_h_carry: bool = False

    @property
    def inner(self) -> int:
        return int(self.proj_factor * self.d_model)

    @property
    def dh_m(self) -> int:       # mLSTM per-head dim
        return self.inner // self.n_heads

    @property
    def dh_s(self) -> int:       # sLSTM per-head dim
        return self.d_model // self.n_heads

    @property
    def layer_kinds(self):
        """('m'|'s') per layer."""
        return tuple("s" if (i + 1) % self.slstm_every == 0 else "m"
                     for i in range(self.num_layers))


# ---------------------------------------------------------------------------
# mLSTM: chunkwise-parallel matrix-memory cell
# ---------------------------------------------------------------------------


def mlstm_chunkwise(q, k, v, lf, li, chunk: int, initial=None):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B, H, S, d); lf: (B, H, S) log-sigmoid forget; li: (B, H, S) log
    input gate (unbounded). Returns (h (B,H,S,d), final (C, n, m)).

      C_t = f_t C_{t-1} + i_t k_t v_t^T ;  n_t = f_t n_{t-1} + i_t k_t
      h_t = (q_t^T C_t) / max(|q_t^T n_t|, exp(-m_t))
    """
    B, H, S, d = q.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    scale = d ** -0.5

    qc = q.reshape(B, H, nc, c, d).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(B, H, nc, c, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, H, nc, c, d).transpose(2, 0, 1, 3, 4)
    lfc = lf.reshape(B, H, nc, c).transpose(2, 0, 1, 3)
    lic = li.reshape(B, H, nc, c).transpose(2, 0, 1, 3)

    if initial is None:
        C0 = jnp.zeros((B, H, d, d), jnp.float32)
        n0 = jnp.zeros((B, H, d), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = initial

    def chunk_step(carry, inp):
        C, n, m = carry                          # stabilized: true C = C*exp(m)
        qq, kk, vv, lff, lii = inp
        b = jnp.cumsum(lff, axis=-1)             # (B,H,c) incl. own lf
        Mt = jax.lax.cummax(lii - b, axis=lii.ndim - 1)  # running max of (li-b)
        m_t = b + jnp.maximum(m[..., None], Mt)  # per-step stabilizer
        w_inter = jnp.exp(m[..., None] + b - m_t)            # (B,H,c)
        # intra decay matrix D[t,tau] = exp(b_t - b_tau + li_tau - m_t), tau<=t
        logD = (b[..., :, None] - b[..., None, :] + lii[..., None, :]
                - m_t[..., :, None])
        tri = jnp.tril(jnp.ones((c, c), bool))
        D = jnp.where(tri[None, None], jnp.exp(logD), 0.0)

        s = jnp.einsum("bhtd,bhsd->bhts", qq, kk,
                       preferred_element_type=jnp.float32) * scale
        inter_h = jnp.einsum("bhtd,bhdv->bhtv", qq, C,
                             preferred_element_type=jnp.float32) * scale
        h_num = (jnp.einsum("bhts,bhsv->bhtv", s * D, vv,
                            preferred_element_type=jnp.float32)
                 + inter_h * w_inter[..., None])
        # normalizer n_t = w_inter * n0 + sum_tau D[t,tau] k_tau
        n_t = (jnp.einsum("bhts,bhsd->bhtd", D, kk,
                          preferred_element_type=jnp.float32)
               + n[..., None, :] * w_inter[..., None])
        qn_t = jnp.einsum("bhtd,bhtd->bht", qq, n_t,
                          preferred_element_type=jnp.float32) * scale
        denom = jnp.maximum(jnp.abs(qn_t), jnp.exp(-m_t))
        h = h_num / denom[..., None]

        # end-of-chunk state
        b_end = b[..., -1:]                      # (B,H,1)
        m_end = b_end[..., 0] + jnp.maximum(m, Mt[..., -1])
        w_c = jnp.exp(b_end[..., 0] + m - m_end)             # carry decay
        w_k = jnp.exp(b_end - b + lii - m_end[..., None])    # (B,H,c)
        C_new = (C * w_c[..., None, None]
                 + jnp.einsum("bhsd,bhsv->bhdv", kk * w_k[..., None], vv,
                              preferred_element_type=jnp.float32))
        n_new = n * w_c[..., None] + jnp.einsum(
            "bhs,bhsd->bhd", w_k, kk, preferred_element_type=jnp.float32)
        return (C_new, n_new, m_end), h

    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                    (qc, kc, vc, lfc, lic))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, d)
    return h.astype(q.dtype), (Cf, nf, mf)


def mlstm_decode(q, k, v, lf, li, state):
    """One-token mLSTM step. q,k,v: (B,H,d); lf,li: (B,H). state=(C,n,m)."""
    C, n, m = state
    d = q.shape[-1]
    scale = d ** -0.5
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(li - m_new)
    C = C * fw[..., None, None] + iw[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = n * fw[..., None] + iw[..., None] * k
    h_num = jnp.einsum("bhd,bhdv->bhv", q, C,
                       preferred_element_type=jnp.float32) * scale
    qn = jnp.einsum("bhd,bhd->bh", q, n,
                    preferred_element_type=jnp.float32) * scale
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    return (h_num / denom[..., None]).astype(q.dtype), (C, n, m_new)


# ---------------------------------------------------------------------------
# sLSTM: scalar-memory cell with true h->h recurrence (paper's RH territory)
# ---------------------------------------------------------------------------


def slstm_step(x_gates, h_prev, state, R, *, rh_state=None, rules=None,
               pin_h=False):
    """One sLSTM step for all heads.

    x_gates: (B, 4*Dh_total) from the input projection (i,f,z,o layout);
    h_prev: (B, H, dh); state: (c, n, m) each (B, H, dh); R: (H, dh, 4dh)
    block-diagonal recurrent weights. ``rh_state`` is the RH structured
    DropoutState: kept-unit ids over dh, shared across heads, re-sampled per
    step (Case-III). The recurrent matmul is compacted accordingly.
    """
    B, H, dh = h_prev.shape
    c, n, m = state
    if rh_state is not None and rh_state.structured:
        ids = _masks.keep_blocks_to_unit_ids(rh_state.keep_blocks,
                                             rh_state.spec.block_size) \
            if rh_state.spec.block_size > 1 else rh_state.keep_blocks
        h_c = jnp.take(h_prev, ids, axis=-1) * rh_state.scale
        R_c = jnp.take(R, ids, axis=1)
        r_gates = jnp.einsum("bhk,hkg->bhg", h_c, R_c,
                             preferred_element_type=jnp.float32)
    elif rh_state is not None and rh_state.dense_mask is not None:
        # mask (B, 1, dh) or (B, dh): broadcast over (shared across) heads
        dm = rh_state.dense_mask
        dm = dm if dm.ndim == 3 else dm[:, None, :]
        hm = h_prev * dm * rh_state.scale
        r_gates = jnp.einsum("bhd,hdg->bhg", hm, R,
                             preferred_element_type=jnp.float32)
    else:
        r_gates = jnp.einsum("bhd,hdg->bhg", h_prev, R,
                             preferred_element_type=jnp.float32)
    gates = x_gates.reshape(B, H, 4 * dh) + r_gates
    gi, gf, gz, go = jnp.split(gates, 4, axis=-1)
    # exponential input gate, sigmoid-in-log-space forget, stabilizer m
    lf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(lf + m, gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(lf + m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
    if rules is not None and pin_h:
        # §Perf (EXPERIMENTS.md xlstm iter 3): replicate the h carry on
        # feature dims so the next step's RH compaction gather (traced
        # kept-unit ids) is LOCAL — otherwise GSPMD all-gathers R/h per
        # time step (~400GB over the step loop at 4k seq). Costs one tiny
        # (B,H,dh) all-gather per step. Confirmed 1.21x on the dominant
        # roofline term.
        h_new = shard_act(h_new, ("batch", None, None), rules)
    return h_new, (c_new, n_new, m_new)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,D), w: (K,D)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _proj_sdrop(x, w, drop_state):
    if drop_state is None or drop_state.inactive:
        return jnp.einsum("bsd,dn->bsn", x, w,
                          preferred_element_type=jnp.float32).astype(x.dtype)
    if drop_state.structured:
        return sm.sdrop_matmul(x, w, drop_state.keep_blocks,
                               rate=drop_state.spec.rate,
                               block_size=drop_state.spec.block_size,
                               scale=drop_state.scale)
    return jnp.einsum("bsd,dn->bsn", drop_state.apply(x), w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def init_mlstm_block(key, cfg: XLSTMConfig, L: int):
    D, I, H = cfg.d_model, cfg.inner, cfg.n_heads
    pd = cfg.param_dtype
    ks = iter(jax.random.split(key, 12))

    def w(shape, axes, scale=None):
        s = scale if scale is not None else shape[-2] ** -0.5
        return tag((jax.random.normal(next(ks), shape) * s).astype(pd), *axes)

    return {
        "ln": {"g": tag(jnp.ones((L, D), pd), "layer", "norm")},
        "w_up": w((L, D, 2 * I), ("layer", "embed", "mlp")),
        "conv_w": tag(jnp.zeros((L, cfg.conv_kernel, I), pd), "layer", "conv", "mlp"),
        "conv_b": tag(jnp.zeros((L, I), pd), "layer", "mlp"),
        "wq": w((L, I, I), ("layer", "mlp", "heads")),
        "wk": w((L, I, I), ("layer", "mlp", "heads")),
        "wv": w((L, I, I), ("layer", "mlp", "heads")),
        "w_gates": w((L, I, 2 * H), ("layer", "mlp", "heads"), scale=I ** -0.5),
        "b_gates": tag(jnp.concatenate(
            [jnp.zeros((L, H)), jnp.linspace(3.0, 6.0, H)[None].repeat(L, 0)],
            -1).astype(pd), "layer", "heads"),
        "gn": {"g": tag(jnp.ones((L, I), pd), "layer", "norm")},
        "w_down": w((L, I, D), ("layer", "mlp", "embed")),
    }


def init_slstm_block(key, cfg: XLSTMConfig, L: int):
    D, H, dh = cfg.d_model, cfg.n_heads, cfg.dh_s
    pd = cfg.param_dtype
    Fu = int(4 * D / 3) // 2 * 2   # gated-FFN width (pf 4/3)
    ks = iter(jax.random.split(key, 8))

    def w(shape, axes, scale=None):
        s = scale if scale is not None else shape[-2] ** -0.5
        return tag((jax.random.normal(next(ks), shape) * s).astype(pd), *axes)

    return {
        "ln": {"g": tag(jnp.ones((L, D), pd), "layer", "norm")},
        "w_gates": w((L, D, 4 * D), ("layer", "embed", "heads")),
        "b_gates": tag(jnp.zeros((L, 4 * D), pd), "layer", "heads"),
        "R": w((L, H, dh, 4 * dh), ("layer", "heads", "head_dim", "state"),
               scale=dh ** -0.5),
        "gn": {"g": tag(jnp.ones((L, D), pd), "layer", "norm")},
        "ln2": {"g": tag(jnp.ones((L, D), pd), "layer", "norm")},
        "w_up1": w((L, D, Fu), ("layer", "embed", "mlp")),
        "w_up2": w((L, D, Fu), ("layer", "embed", "mlp")),
        "w_down": w((L, Fu, D), ("layer", "mlp", "embed")),
    }


def init_params(key, cfg: XLSTMConfig):
    kinds = cfg.layer_kinds
    n_m, n_s = kinds.count("m"), kinds.count("s")
    k_e, k_m, k_s, k_h = jax.random.split(key, 4)
    p = {
        "embed": tag((jax.random.normal(k_e, (cfg.vocab, cfg.d_model)) * 0.02
                      ).astype(cfg.param_dtype), "vocab", "embed"),
        "mlstm": init_mlstm_block(k_m, cfg, n_m) if n_m else None,
        "slstm": init_slstm_block(k_s, cfg, n_s) if n_s else None,
        "ln_f": {"g": tag(jnp.ones((cfg.d_model,), cfg.param_dtype), "norm")},
        "lm_head": tag((jax.random.normal(k_h, (cfg.d_model, cfg.vocab))
                        * cfg.d_model ** -0.5).astype(cfg.param_dtype),
                       "embed", "vocab"),
    }
    return p


def _rms(g, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * g).astype(x.dtype)


def _group_rms(g, x, H, eps=1e-6):
    """Per-head RMS norm over the head dim. x: (..., H*dh)."""
    shp = x.shape
    xf = x.reshape(*shp[:-1], H, shp[-1] // H).astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y.reshape(shp) * g).astype(x.dtype)


def mlstm_block_apply(pl, x, cfg: XLSTMConfig, drop_state=None, initial=None,
                      rules=None, return_conv=False):
    """x: (B,S,D) -> (B,S,D). Returns (y, final_state).

    ``return_conv=True`` additionally returns the depthwise-conv ring
    buffer (the last conv_kernel-1 pre-conv ``u`` rows, zero-padded at the
    front for short prompts) as ``(y, (final_state, conv_tail))`` — the
    serving prefill needs it to hand off to ``decode_step``.
    """
    B, S, D = x.shape
    H, I = cfg.n_heads, cfg.inner
    h = _rms(pl["ln"]["g"], x)
    up = _proj_sdrop(h, pl["w_up"], drop_state)          # NR structured drop
    u, z = jnp.split(up, 2, axis=-1)
    uc = jax.nn.silu(_causal_conv(u, pl["conv_w"], pl["conv_b"]))
    q = jnp.einsum("bsi,ij->bsj", uc, pl["wq"]).reshape(B, S, H, -1)
    k = jnp.einsum("bsi,ij->bsj", uc, pl["wk"]).reshape(B, S, H, -1)
    v = jnp.einsum("bsi,ij->bsj", u, pl["wv"]).reshape(B, S, H, -1)
    gates = jnp.einsum("bsi,ig->bsg", uc, pl["w_gates"]) + pl["b_gates"]
    li, gf = jnp.split(gates, 2, axis=-1)                # (B,S,H) each
    lf = jax.nn.log_sigmoid(gf)
    # §Perf note (EXPERIMENTS.md, xlstm iterations 1-2): explicit q/k/v
    # layout pinning before the chunk scan was tried twice (full feature
    # replication; dv-sharded cell) and REFUTED both times — GSPMD's bwd
    # pass hits involuntary full rematerialization on the pinned layouts.
    # The mLSTM chunk scan is left to GSPMD propagation.
    hcell, state = mlstm_chunkwise(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), lf.transpose(0, 2, 1),
        li.transpose(0, 2, 1), cfg.chunk, initial=initial)
    hcell = hcell.transpose(0, 2, 1, 3).reshape(B, S, I)
    out = _group_rms(pl["gn"]["g"], hcell, H) * jax.nn.silu(z)
    y = jnp.einsum("bsi,id->bsd", out, pl["w_down"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if return_conv:
        K = cfg.conv_kernel
        tail = u[:, max(0, S - (K - 1)):, :]
        if S < K - 1:
            tail = jnp.pad(tail, ((0, 0), (K - 1 - S, 0), (0, 0)))
        return x + y, (state, tail)
    return x + y, state


def slstm_block_apply(pl, x, cfg: XLSTMConfig, nr_state=None, ctx=None,
                      rh_site: str = "slstm/rh",
                      initial=None, step0: int = 0, rules=None,
                      lengths=None):
    """sLSTM block with scan over time; RH structured dropout per step.

    ``lengths`` (B,) int32 marks ragged rows: carries (h, c, n, m) freeze
    past each row's length so the returned final state matches a per-row
    unpacked run. The freeze predicate uses the *within-sequence* index
    (``t - step0``) — ``step0`` only shifts the mask-schedule time axis.
    """
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.dh_s
    h = _rms(pl["ln"]["g"], x)
    xg = _proj_sdrop(h, pl["w_gates"], nr_state) + pl["b_gates"]  # (B,S,4D)

    if initial is None:
        zeros = jnp.zeros((B, H, dh), jnp.float32)
        h0, st0 = zeros, (zeros, zeros, jnp.full((B, H, dh), -1e30))
    else:
        h0, st0 = initial

    rh_active = (ctx is not None and not ctx.deterministic
                 and ctx.spec(rh_site).active)
    rh_sched, rh_xs, rh_const = None, None, None
    if rh_active and cfg.engine != "stepwise":
        # Phase A: the whole RH mask schedule, sampled pre-scan; the mask
        # is shared across heads ((B, 1, dh) broadcasts in slstm_step).
        # PER_STEP rows thread as scan xs; FIXED masks are a scan constant.
        rh_sched = ctx.schedule(rh_site, S, (B, 1), dh, t0=step0)
        rh_xs = rh_sched.scan_rows()
        if rh_xs is None:
            rh_const = rh_sched.state(0)

    if cfg.engine == "fused":
        # Phase B as ONE kernels/slstm_scan call: R resident across steps,
        # compact per-step RH gathers off the schedule ids table, pointwise
        # exponential-gating update + reverse-time backward fused. The
        # kernel impl follows the RH site's spec.impl ("pallas" = the
        # persistent-scan Pallas kernel, interpret mode off TPU; "xla" =
        # the same fused two-pass structure as lax.scans — the CPU path).
        from repro.kernels import ops as _kops
        kw, impl = {}, "xla"
        if rh_sched is not None and not rh_sched.inactive:
            impl = rh_sched.spec.impl
            if rh_sched.structured:
                kw = dict(keep_blocks=rh_sched.keep_blocks,
                          block_size=rh_sched.spec.block_size,
                          scale=rh_sched.scale)
            else:
                kw = dict(dense_mask=rh_sched.dense_mask,
                          scale=rh_sched.scale)
        xgh = xg.transpose(1, 0, 2).reshape(S, B, H, 4 * dh)
        hs, (hf, stf) = _kops.slstm_scan(xgh, pl["R"], h0, *st0,
                                         impl=impl, lengths=lengths, **kw)
        hs = hs.transpose(1, 0, 2, 3)
    else:
        def step(carry, inp):
            h_prev, st = carry
            xg_t, t, rh_row = inp
            rh = None
            if rh_sched is not None:
                rh = (rh_const if rh_row is None
                      else rh_sched.state_for_row(rh_row))
            elif rh_active:
                rh = ctx.state(rh_site, (B, 1), dh, t=t)
            h_new, st_new = slstm_step(xg_t, h_prev, st, pl["R"],
                                       rh_state=rh, rules=rules,
                                       pin_h=cfg.pin_h_carry)
            if lengths is not None:
                act = ((t - step0) < lengths)[:, None, None]
                h_new = jnp.where(act, h_new, h_prev)
                st_new = tuple(jnp.where(act, v, s)
                               for v, s in zip(st_new, st))
            return (h_new, st_new), h_new

        (hf, stf), hs = jax.lax.scan(step, (h0, st0),
                                     (xg.transpose(1, 0, 2),
                                      step0 + jnp.arange(S), rh_xs))
        hs = hs.transpose(1, 0, 2, 3)
    hs = hs.reshape(B, S, D).astype(x.dtype)
    out = _group_rms(pl["gn"]["g"], hs, H)
    x = x + out
    # gated FFN (pf 4/3)
    h2 = _rms(pl["ln2"]["g"], x)
    u1 = _proj_sdrop(h2, pl["w_up1"], nr_state)
    u2 = _proj_sdrop(h2, pl["w_up2"], nr_state)
    y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(u1) * u2, pl["w_down"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return x + y, (hf, stf)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def forward(params, tokens, cfg: XLSTMConfig, *, rules=None, ctx=None,
            lengths=None):
    """tokens (B, S) -> features (B, S, D).

    ``lengths`` (B,) int32 marks a ragged batch. Both block families are
    causal, so real-token features never see padding; the lengths are
    threaded into the sLSTM blocks so their recurrent carries freeze at
    each row's last real token (mLSTM needs no freeze for the loss — its
    chunkwise form is causal — and ``forward`` discards final states).
    """
    if ctx is None:
        ctx = cfg.plan.bind(None)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = shard_act(x, ("batch", "seq", "embed_act"), rules)
    kinds = cfg.layer_kinds
    n_groups = kinds.count("s")
    per_group = cfg.slstm_every - 1

    def m_scan(x, blocks, base, count):
        def body(x, inp):
            pl, li = inp
            # layer index = the depth-scan time axis; inactive sites yield
            # a no-op state inside ctx.state
            ds = ctx.state("mlstm/nr", x.shape[:2], cfg.d_model, t=li)
            y, _ = mlstm_block_apply(pl, x, cfg, drop_state=ds, rules=rules)
            return y, None
        f = jax.checkpoint(body) if cfg.remat != "none" else body
        x, _ = jax.lax.scan(f, x, (blocks, base + jnp.arange(count)))
        return x

    if n_groups == 0:
        return _finish(params, m_scan(x, params["mlstm"], 0, len(kinds)), cfg)

    # groups of (per_group mLSTM + 1 sLSTM), then trailing mLSTMs
    mt = params["mlstm"]
    st = params["slstm"]
    mi = 0
    for g in range(n_groups):
        if per_group:      # slstm_every=1 -> all-sLSTM, no mLSTM sub-stack
            grp = jax.tree.map(lambda a: a[mi:mi + per_group], mt)
            x = m_scan(x, grp, g * cfg.slstm_every, per_group)
        sl = jax.tree.map(lambda a: a[g], st)
        nr = ctx.state("slstm/nr", x.shape[:2], cfg.d_model,
                       t=g * cfg.slstm_every + per_group)
        x, _ = slstm_block_apply(sl, x, cfg, nr_state=nr, ctx=ctx,
                                 rh_site=f"slstm{g}/rh", rules=rules,
                                 lengths=lengths)
        mi += per_group
    n_m = kinds.count("m")
    if mi < n_m:
        grp = jax.tree.map(lambda a: a[mi:], mt)
        x = m_scan(x, grp, n_groups * cfg.slstm_every, n_m - mi)
    return _finish(params, x, cfg)


def _finish(params, x, cfg):
    return _rms(params["ln_f"]["g"], x)


def prefill(params, tokens, cfg: XLSTMConfig, *, rules=None):
    """Teacher-forced pass that also fills the recurrent decode state.

    Runs the same eval-mode block stack as ``forward`` but threads every
    block's final recurrent state into the ``init_state`` serving layout:
    mLSTM (C, n, m) + the depthwise-conv ring buffer, sLSTM (h, c, n, m)
    **including the exponential-gating stabilizer ``m``** — so
    ``decode_step`` continues exactly where the prompt left off (the
    recurrent long_500k path; fused-trained params hand off through here).
    Returns ``(feats, state)``.

    The block traversal mirrors ``forward`` and must stay in lockstep
    with it (same group loop / trailing-mLSTM bookkeeping); dropout is
    off here (eval ctx), which is why the per-group rh_site naming and
    nr states of ``forward`` are not threaded through.
    """
    ctx = cfg.plan.bind(None)                       # eval: dropout off
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = shard_act(x, ("batch", "seq", "embed_act"), rules)
    kinds = cfg.layer_kinds
    n_groups = kinds.count("s")
    per_group = cfg.slstm_every - 1
    state = init_state(cfg, B)

    def m_scan(x, blocks, lo, hi):
        def body(x, pl_):
            y, (st, conv) = mlstm_block_apply(pl_, x, cfg, rules=rules,
                                              return_conv=True)
            return y, (st, conv)
        x, ((C, n, m), conv) = jax.lax.scan(body, x, blocks)
        state["m_C"] = state["m_C"].at[lo:hi].set(C.astype(state["m_C"].dtype))
        state["m_n"] = state["m_n"].at[lo:hi].set(n.astype(state["m_n"].dtype))
        state["m_m"] = state["m_m"].at[lo:hi].set(m.astype(state["m_m"].dtype))
        state["m_conv"] = state["m_conv"].at[lo:hi].set(
            conv.astype(state["m_conv"].dtype))
        return x

    mt, st_p = params["mlstm"], params.get("slstm")
    mi = 0
    for g in range(n_groups):
        if per_group:      # slstm_every=1 -> all-sLSTM, no mLSTM sub-stack
            grp = jax.tree.map(lambda a: a[mi:mi + per_group], mt)
            x = m_scan(x, grp, mi, mi + per_group)
        sl = jax.tree.map(lambda a: a[g], st_p)
        x, (hf, (cf, nf, mf)) = slstm_block_apply(sl, x, cfg, ctx=ctx,
                                                  rules=rules)
        for key, v in (("s_h", hf), ("s_c", cf), ("s_n", nf), ("s_m", mf)):
            state[key] = state[key].at[g].set(v.astype(state[key].dtype))
        mi += per_group
    n_m = kinds.count("m")
    if mi < n_m:
        grp = jax.tree.map(lambda a: a[mi:], mt)
        x = m_scan(x, grp, mi, n_m)
    return _finish(params, x, cfg), state


def lm_logits(params, feats):
    return jnp.einsum("bsd,dv->bsv", feats, params["lm_head"],
                      preferred_element_type=jnp.float32)


def loss_fn(params, batch, cfg: XLSTMConfig, *, rules=None, drop_key=None,
            step=0, shard=None):
    """Mean NLL — per *real* token when the batch carries "lengths"."""
    ctx = cfg.plan.bind(drop_key, step, shard=shard)
    lengths = batch.get("lengths")
    feats = forward(params, batch["tokens"], cfg, rules=rules, ctx=ctx,
                    lengths=lengths)
    if lengths is not None:
        mask = metrics.length_mask(lengths, batch["tokens"].shape[1])
        B, S = batch["tokens"].shape
        chunk = max(1, -(-(B * S) // cfg.loss_chunks))
        return metrics.masked_lm_loss({"w": params["lm_head"]}, feats,
                                      batch["labels"], mask, chunk=chunk)
    tcfg = T.TransformerConfig(vocab=cfg.vocab, d_model=cfg.d_model,
                               loss_chunks=cfg.loss_chunks)
    return T.lm_loss({"lm_head": params["lm_head"]}, feats, batch["labels"],
                     tcfg, rules=rules)


# ------------------------------- serving ----------------------------------


def init_state(cfg: XLSTMConfig, batch: int, dtype=jnp.float32):
    """Recurrent serving state (O(1) per token; long_500k runs on this).

    Cell states (C/n/m) are fp32 for numerical stability over 500k steps;
    the conv ring buffer matches the compute dtype (it feeds matmuls)."""
    kinds = cfg.layer_kinds
    n_m, n_s = kinds.count("m"), kinds.count("s")
    H, dm, dh = cfg.n_heads, cfg.dh_m, cfg.dh_s
    state = {
        "m_C": jnp.zeros((n_m, batch, H, dm, dm), dtype),
        "m_n": jnp.zeros((n_m, batch, H, dm), dtype),
        "m_m": jnp.full((n_m, batch, H), -1e30, dtype),
        "m_conv": jnp.zeros((n_m, batch, cfg.conv_kernel - 1, cfg.inner),
                            cfg.compute_dtype),
    }
    if n_s:
        state.update({
            "s_h": jnp.zeros((n_s, batch, H, dh), dtype),
            "s_c": jnp.zeros((n_s, batch, H, dh), dtype),
            "s_n": jnp.zeros((n_s, batch, H, dh), dtype),
            "s_m": jnp.full((n_s, batch, H, dh), -1e30, dtype),
        })
    return state


def decode_step(params, cfg: XLSTMConfig, state, tokens, pos, *, rules=None):
    """One-token decode. tokens: (B,1). Returns (logits, new state)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(
        cfg.compute_dtype)                                   # (B, D)
    kinds = cfg.layer_kinds
    H, I = cfg.n_heads, cfg.inner

    def m_body(carry, inp):
        x = carry
        pl, C, n, m, conv = inp
        h = _rms(pl["ln"]["g"], x)
        up = h @ pl["w_up"]
        u, z = jnp.split(up, 2, axis=-1)
        win = jnp.concatenate([conv, u[:, None, :]], axis=1)  # (B,K,I)
        uc = jax.nn.silu(jnp.einsum("bki,ki->bi", win, pl["conv_w"])
                         + pl["conv_b"])
        q = (uc @ pl["wq"]).reshape(B, H, -1)
        k = (uc @ pl["wk"]).reshape(B, H, -1)
        v = (u @ pl["wv"]).reshape(B, H, -1)
        g = uc @ pl["w_gates"] + pl["b_gates"]
        li, gf = jnp.split(g, 2, axis=-1)
        lf = jax.nn.log_sigmoid(gf)
        hc, (C2, n2, m2) = mlstm_decode(q, k, v, lf, li, (C, n, m))
        out = _group_rms(pl["gn"]["g"], hc.reshape(B, I), H) * jax.nn.silu(z)
        y = x + out @ pl["w_down"]
        return y, (C2, n2, m2, win[:, 1:])

    def s_body(x, pl, h_prev, st):
        h = _rms(pl["ln"]["g"], x)
        xg = h @ pl["w_gates"] + pl["b_gates"]
        h_new, st_new = slstm_step(xg, h_prev, st, pl["R"])
        out = _group_rms(pl["gn"]["g"], h_new.reshape(B, -1), H).astype(x.dtype)
        x = x + out
        h2 = _rms(pl["ln2"]["g"], x)
        y = (jax.nn.gelu(h2 @ pl["w_up1"]) * (h2 @ pl["w_up2"])) @ pl["w_down"]
        return x + y.astype(x.dtype), h_new, st_new

    new_state = dict(state)
    n_groups = kinds.count("s")
    per_group = cfg.slstm_every - 1
    mt, st_p = params["mlstm"], params.get("slstm")

    # scan across mLSTM groups is unrolled at the python level over groups
    # (few groups), each group scanning its stacked layers.
    def run_m(x, lo, hi):
        grp = jax.tree.map(lambda a: a[lo:hi], mt)
        seg = (grp, state["m_C"][lo:hi], state["m_n"][lo:hi],
               state["m_m"][lo:hi], state["m_conv"][lo:hi])

        def body(x, inp):
            return m_body(x, inp)
        x, outs = jax.lax.scan(body, x, seg)
        C2, n2, m2, conv2 = outs
        new_state["m_C"] = new_state["m_C"].at[lo:hi].set(C2)
        new_state["m_n"] = new_state["m_n"].at[lo:hi].set(n2)
        new_state["m_m"] = new_state["m_m"].at[lo:hi].set(m2)
        new_state["m_conv"] = new_state["m_conv"].at[lo:hi].set(conv2)
        return x

    mi = 0
    for g in range(n_groups):
        if per_group:      # slstm_every=1 -> all-sLSTM, no mLSTM sub-stack
            x = run_m(x, mi, mi + per_group)
        sl = jax.tree.map(lambda a: a[g], st_p)
        stt = (state["s_c"][g], state["s_n"][g], state["s_m"][g])
        x, h_new, st_new = s_body(x, sl, state["s_h"][g], stt)
        new_state["s_h"] = new_state["s_h"].at[g].set(h_new)
        new_state["s_c"] = new_state["s_c"].at[g].set(st_new[0])
        new_state["s_n"] = new_state["s_n"].at[g].set(st_new[1])
        new_state["s_m"] = new_state["s_m"].at[g].set(st_new[2])
        mi += per_group
    n_m = kinds.count("m")
    if mi < n_m:
        x = run_m(x, mi, n_m)
    feats = _rms(params["ln_f"]["g"], x)
    logits = feats @ params["lm_head"]
    return logits[:, None, :], new_state
