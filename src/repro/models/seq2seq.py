"""Luong'15 attention NMT (paper Table 2): 2-layer unidirectional LSTM
encoder-decoder with general attention + input feeding.

Dropout comes from a ``DropoutPlan`` over named sites — "nr" / "rh" resolve
for both stacks (full site names "enc/layer0/nr", "dec/layer1/rh", ... keep
the PRNG streams independent), and "out" covers the encoder/decoder output
dropout of the paper's §4.2 modification.

``cfg.engine`` selects the recurrent execution path. The encoder runs the
full engine (lstm_stack ``engine="scheduled"`` two-phase, or ``"fused"`` —
the whole Phase-B recurrence in one persistent-scan kernel per layer). The
decoder's NR input is ``[embed_t ; h~_{t-1}]`` — *input feeding* makes it
sequentially dependent, so its NR matmul cannot leave the scan (and the
attention inside the step keeps the decode loop out of the fused kernel);
the scheduled and fused engines still hoist all mask sampling (Phase A
schedules threaded through as scan xs — no PRNG calls in the decode scan
body).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core import lstm as lstm_mod
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec


@dataclasses.dataclass(frozen=True)
class NMTConfig:
    name: str = "luong_nmt"
    src_vocab: int = 50000
    tgt_vocab: int = 50000
    embed: int = 512
    hidden: int = 512
    num_layers: int = 2
    plan: DropoutPlan = DropoutPlan({"nr": DropoutSpec(rate=0.3)})
    engine: str = "scheduled"      # recurrent engine (see module docstring)
    param_dtype: Any = jnp.float32


def init_params(key, cfg: NMTConfig):
    ks = jax.random.split(key, 8)
    H = cfg.hidden
    return {
        "src_embed": L.uniform_init(ks[0], (cfg.src_vocab, cfg.embed), 0.1),
        "tgt_embed": L.uniform_init(ks[1], (cfg.tgt_vocab, cfg.embed), 0.1),
        "encoder": lstm_mod.init_lstm_params(ks[2], cfg.embed, H,
                                             cfg.num_layers),
        # decoder consumes [embed ; input-feed h~] per step
        "decoder": lstm_mod.init_lstm_params(ks[3], cfg.embed + H, H,
                                             cfg.num_layers),
        "w_att": L.init_dense(ks[4], H, H, bias=False),     # general score
        "w_comb": L.init_dense(ks[5], 2 * H, H, bias=False),
        "fc": L.init_dense(ks[6], H, cfg.tgt_vocab),
    }


def encode(params, src, cfg: NMTConfig, *, ctx=None):
    if ctx is None:
        ctx = cfg.plan.bind(None)
    B, S = src.shape
    x = jnp.take(params["src_embed"], src, axis=0)
    state = lstm_mod.zero_state(cfg.num_layers, B, cfg.hidden)
    ys, state = lstm_mod.lstm_stack(
        params["encoder"], x.transpose(1, 0, 2), state, ctx=ctx, site="enc",
        engine=cfg.engine)
    enc = ys.transpose(1, 0, 2)                            # (B,S,H)
    enc = ctx.apply("enc/out", enc)
    return enc, state


def decode_train(params, tgt_in, enc_out, enc_state, cfg: NMTConfig, *,
                 ctx=None, src_mask=None):
    """Teacher-forced decoding with Luong general attention + input feeding.

    tgt_in: (B, St); enc_out: (B, Ss, H). Returns logits (B, St, V).
    """
    if ctx is None:
        ctx = cfg.plan.bind(None)
    B, St = tgt_in.shape
    H = cfg.hidden
    x = jnp.take(params["tgt_embed"], tgt_in, axis=0)      # (B,St,E)
    enc_proj = L.dense(params["w_att"], enc_out)           # (B,Ss,H)
    if src_mask is None:
        src_mask = jnp.ones(enc_out.shape[:2], bool)

    dec_params = params["decoder"]
    nl = cfg.num_layers
    in_dims = [cfg.embed + H] + [H] * (nl - 1)

    # fused hoists mask sampling exactly like scheduled here — the decode
    # loop itself stays a lax.scan (input feeding + attention in the body).
    scheduled = cfg.engine != "stepwise"
    if scheduled:
        # Phase A: all T steps' masks for every decoder site, sampled
        # pre-scan. PER_STEP rows ride through the scan as xs, FIXED masks
        # are closed over as scan constants — no in-scan PRNG either way.
        # Input feeding ([embed_t ; h~_{t-1}] entering W) keeps the NR
        # matmul itself inside the scan — it is sequentially dependent.
        nr_scheds = [ctx.schedule(f"dec/layer{l}/nr", St, B, in_dims[l])
                     for l in range(nl)]
        rh_scheds = [ctx.schedule(f"dec/layer{l}/rh", St, B, H)
                     for l in range(nl)]
        drop_xs = ([s.scan_rows() for s in nr_scheds],
                   [s.scan_rows() for s in rh_scheds])
        nr_const = [s.state(0) if r is None else None
                    for s, r in zip(nr_scheds, drop_xs[0])]
        rh_const = [s.state(0) if r is None else None
                    for s, r in zip(rh_scheds, drop_xs[1])]
    else:
        drop_xs = None

    def drop_states(t, rows):
        if scheduled:
            nr_rows, rh_rows = rows
            return ([nr_const[l] if nr_rows[l] is None
                     else nr_scheds[l].state_for_row(nr_rows[l])
                     for l in range(nl)],
                    [rh_const[l] if rh_rows[l] is None
                     else rh_scheds[l].state_for_row(rh_rows[l])
                     for l in range(nl)])
        return ([ctx.state(f"dec/layer{l}/nr", B, in_dims[l], t=t)
                 for l in range(nl)],
                [ctx.state(f"dec/layer{l}/rh", B, H, t=t) for l in range(nl)])

    def step(carry, inp):
        (hs, cs, feed) = carry
        x_t, t, rows = inp                                 # x_t: (B,E)
        inp_t = jnp.concatenate([x_t, feed], axis=-1)
        nr_sts, rh_sts = drop_states(t, rows)
        new_h, new_c = [], []
        cur = inp_t
        for l in range(nl):
            h, c = lstm_mod.lstm_cell(dec_params[l], cur, hs[l], cs[l],
                                      nr_sts[l], rh_sts[l])
            new_h.append(h)
            new_c.append(c)
            cur = h
        # Luong general attention on the top hidden state
        scores = jnp.einsum("bh,bsh->bs", cur, enc_proj)
        scores = jnp.where(src_mask, scores, -1e30)
        alpha = jax.nn.softmax(scores, axis=-1)
        ctx_vec = jnp.einsum("bs,bsh->bh", alpha, enc_out)
        h_tilde = jnp.tanh(L.dense(params["w_comb"],
                                   jnp.concatenate([ctx_vec, cur], -1)))
        return (jnp.stack(new_h), jnp.stack(new_c), h_tilde), h_tilde

    h0 = enc_state.h
    c0 = enc_state.c
    feed0 = jnp.zeros((B, H), x.dtype)
    (_, _, _), h_tildes = jax.lax.scan(
        step, (h0, c0, feed0),
        (x.transpose(1, 0, 2), jnp.arange(St), drop_xs))
    ht = h_tildes.transpose(1, 0, 2)                       # (B,St,H)
    ht = ctx.apply("dec/out", ht)
    return L.dense(params["fc"], ht).astype(jnp.float32)


def loss_fn(params, batch, cfg: NMTConfig, *, drop_key=None, rules=None,
            step=0):
    """batch: {"src", "tgt_in", "tgt_out", ["src_mask", "tgt_mask"]}."""
    ctx = cfg.plan.bind(drop_key, step)
    enc, st = encode(params, batch["src"], cfg, ctx=ctx)
    logits = decode_train(params, batch["tgt_in"], enc, st, cfg,
                          ctx=ctx, src_mask=batch.get("src_mask"))
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, batch["tgt_out"][..., None], -1)[..., 0]
    mask = batch.get("tgt_mask")
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
