"""Luong'15 attention NMT (paper Table 2): 2-layer unidirectional LSTM
encoder-decoder with general attention + input feeding.

Dropout comes from a ``DropoutPlan`` over named sites — "nr" / "rh" resolve
for both stacks (full site names "enc/layer0/nr", "dec/feed/nr", ... keep
the PRNG streams independent), and "out" covers the encoder/decoder output
dropout of the paper's §4.2 modification.

``cfg.engine`` selects the recurrent execution path for BOTH stacks. The
encoder runs the standard lstm_stack engines. The decoder historically kept
its whole NR matmul in-scan — input feeding makes step t's NR input
``[embed_t ; h~_{t-1}]`` depend on step t-1's attention output — but that
joint matmul splits exactly:

    [embed_t ; h~_{t-1}] @ W  ==  embed_t @ W  +  h~_{t-1} @ W_feed

so the decoder params keep W with embed-only fan-in plus a separate
``w_feed``, and teacher-forced decoding is TWO PASSES:

  * **pass 1** — the recurrence. The embed half of layer 0's NR matmul has
    no sequential dependence: it hoists out of the scan and runs
    time-batched through ``dense_sdrop_scheduled`` at (1-p) FLOPs (site
    "dec/layer0/nr", bias folded in). The feed half stays recurrent and is
    carried INSIDE the scan as one more compact-gathered matmul (site
    "dec/feed/nr") next to the RH matmuls; attention cannot leave the scan
    (h~_{t-1} -> gates_t -> h_t -> attention_t -> h~_t is a nonlinear
    chain) so each step's Luong attention + h~ readout runs in-scan too.
    Under ``engine="fused"`` the whole pass is ONE ``kernels.decoder_scan``
    call with a hand-derived fused reverse-time backward, so fwd AND bwd
    run at (1-p) recurrent FLOPs; ``engine="scheduled"`` is the same
    restructure as a lax.scan; ``engine="stepwise"`` is the per-step-mask
    in-scan oracle.
  * **pass 2** — everything after the h~ sequence exists is time-batched:
    output dropout ("dec/out") + the vocab projection over all T steps at
    once. (Attention already ran in pass 1 — its per-step outputs are the
    recurrent feed — so pass 2 has no per-step work left.)

This restructure is exact only under teacher forcing (the target inputs
for all T steps are known up front). Free-running inference uses the
single-step path: ``init_state`` / ``prefill`` / ``decode_step`` below
serve through ``serving.DecodeEngine`` token by token.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core import lstm as lstm_mod
from repro.core import metrics
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec


@dataclasses.dataclass(frozen=True)
class NMTConfig:
    name: str = "luong_nmt"
    src_vocab: int = 50000
    tgt_vocab: int = 50000
    embed: int = 512
    hidden: int = 512
    num_layers: int = 2
    plan: DropoutPlan = DropoutPlan({"nr": DropoutSpec(rate=0.3)})
    engine: str = "scheduled"      # recurrent engine (see module docstring)
    param_dtype: Any = jnp.float32


def init_params(key, cfg: NMTConfig):
    ks = jax.random.split(key, 8)
    H = cfg.hidden
    return {
        "src_embed": L.uniform_init(ks[0], (cfg.src_vocab, cfg.embed), 0.1),
        "tgt_embed": L.uniform_init(ks[1], (cfg.tgt_vocab, cfg.embed), 0.1),
        "encoder": lstm_mod.init_lstm_params(ks[2], cfg.embed, H,
                                             cfg.num_layers),
        # decoder layer 0 consumes the embed only; the input-feed half of
        # the old joint [embed ; h~] matmul is the separate w_feed below
        "decoder": lstm_mod.init_lstm_params(ks[3], cfg.embed, H,
                                             cfg.num_layers),
        "w_feed": L.uniform_init(ks[7], (H, 4 * H), 0.05),
        "w_att": L.init_dense(ks[4], H, H, bias=False),     # general score
        "w_comb": L.init_dense(ks[5], 2 * H, H, bias=False),
        "fc": L.init_dense(ks[6], H, cfg.tgt_vocab),
    }


def encode(params, src, cfg: NMTConfig, *, ctx=None, lengths=None):
    """src (B, S) -> (enc_out (B, S, H), final state).

    ``lengths`` (B,) int32 marks ragged sources: each row's encoder state
    freezes at its last real token, so the state handed to the decoder is
    the same one an unpacked per-row encode would produce."""
    if ctx is None:
        ctx = cfg.plan.bind(None)
    B, S = src.shape
    x = jnp.take(params["src_embed"], src, axis=0)
    state = lstm_mod.zero_state(cfg.num_layers, B, cfg.hidden)
    ys, state = lstm_mod.lstm_stack(
        params["encoder"], x.transpose(1, 0, 2), state, ctx=ctx, site="enc",
        engine=cfg.engine, lengths=lengths)
    enc = ys.transpose(1, 0, 2)                            # (B,S,H)
    enc = ctx.apply("enc/out", enc)
    return enc, state


def _scan_site_names(nl):
    """The decoder's IN-SCAN dropout sites, in ``kernels.decoder_scan``'s
    canonical order [feed, rh_0..rh_{nl-1}, nr_1..nr_{nl-1}]. (Layer 0's
    NR site "dec/layer0/nr" is the hoisted Phase-A one — not in-scan.)"""
    return (["dec/feed/nr"]
            + [f"dec/layer{l}/rh" for l in range(nl)]
            + [f"dec/layer{l}/nr" for l in range(1, nl)])


def _attend(params, cur, enc_proj, enc_out, score_bias):
    """Luong general attention + h~ readout for one step's top state."""
    scores = jnp.einsum("bh,bsh->bs", cur, enc_proj) + score_bias
    alpha = jax.nn.softmax(scores, axis=-1)
    ctx_vec = jnp.einsum("bs,bsh->bh", alpha, enc_out)
    return jnp.tanh(L.dense(params["w_comb"],
                            jnp.concatenate([ctx_vec, cur], -1)))


def _dec_step(params, nl, carry, gx0_t, sts, enc_proj, enc_out, score_bias):
    """One decoder step given the precomputed layer-0 embed gates ``gx0_t``
    (bias folded) and the in-scan sites' DropoutStates ``sts`` (canonical
    order, None = eval)."""
    dec = params["decoder"]
    hs, cs, feed = carry
    g = (gx0_t
         + L.dense_sdrop({"w": params["w_feed"]}, feed, sts[0])
         + L.dense_sdrop({"w": dec[0]["U"]}, hs[0], sts[1]))
    h, c = lstm_mod.lstm_pointwise(g, cs[0])
    new_h, new_c = [h], [c]
    cur = h
    for l in range(1, nl):
        g = (L.dense_sdrop({"w": dec[l]["W"], "b": dec[l]["b"]}, cur,
                           sts[nl + l])
             + L.dense_sdrop({"w": dec[l]["U"]}, hs[l], sts[1 + l]))
        h, c = lstm_mod.lstm_pointwise(g, cs[l])
        new_h.append(h)
        new_c.append(c)
        cur = h
    h_tilde = _attend(params, cur, enc_proj, enc_out, score_bias)
    return (jnp.stack(new_h), jnp.stack(new_c), h_tilde), h_tilde


def _site_args(sched):
    """MaskSchedule -> decoder_scan's (keep_blocks, dense_mask, bs, scale)."""
    if sched.inactive:
        return (None, None, 1, 1.0)
    if sched.structured:
        return (sched.keep_blocks, None, sched.spec.block_size, sched.scale)
    return (None, sched.dense_mask, 1, sched.scale)


def decode_train(params, tgt_in, enc_out, enc_state, cfg: NMTConfig, *,
                 ctx=None, src_mask=None, tgt_lengths=None):
    """Teacher-forced decoding with Luong general attention + input feeding.

    tgt_in: (B, St); enc_out: (B, Ss, H). Returns logits (B, St, V).
    Two-pass restructure per the module docstring; ``cfg.engine`` picks the
    pass-1 execution (stepwise oracle / scheduled scan / fused kernel).
    ``tgt_lengths`` (B,) int32 marks ragged targets: every decoder carry
    (h_l, c_l, feed) freezes past each row's length and frozen steps cost
    zero gradient — identical across all three engines.
    """
    if ctx is None:
        ctx = cfg.plan.bind(None)
    B, St = tgt_in.shape
    H = cfg.hidden
    nl = cfg.num_layers
    dec = params["decoder"]
    x = jnp.take(params["tgt_embed"], tgt_in, axis=0)      # (B,St,E)
    x_seq = x.transpose(1, 0, 2)                           # (St,B,E)
    enc_proj = L.dense(params["w_att"], enc_out)           # (B,Ss,H)
    if src_mask is None:
        src_mask = jnp.ones(enc_out.shape[:2], bool)
    score_bias = jnp.where(src_mask, 0.0, -1e30).astype(jnp.float32)
    h0, c0 = enc_state.h, enc_state.c
    feed0 = jnp.zeros((B, H), x.dtype)
    site_names = _scan_site_names(nl)

    def freeze(carry_new, carry_old, t):
        """Ragged carry freeze: rows past their length keep t-1's state."""
        if tgt_lengths is None:
            return carry_new
        act = t < tgt_lengths                              # (B,)
        nh, nc, nf = carry_new
        oh, oc, of_ = carry_old
        return (jnp.where(act[None, :, None], nh, oh),
                jnp.where(act[None, :, None], nc, oc),
                jnp.where(act[:, None], nf, of_))

    if cfg.engine == "stepwise":
        # oracle: everything in-scan, masks drawn per step via ctx.state
        # (row t of a schedule is bit-identical — same per-step key).
        def step(carry, xs):
            x_t, t = xs
            gx0_t = L.dense_sdrop(
                {"w": dec[0]["W"], "b": dec[0]["b"]}, x_t,
                ctx.state("dec/layer0/nr", B, cfg.embed, t=t))
            sts = [ctx.state(n, B, H, t=t) for n in site_names]
            new_carry, _ = _dec_step(params, nl, carry, gx0_t, sts,
                                     enc_proj, enc_out, score_bias)
            new_carry = freeze(new_carry, carry, t)
            return new_carry, new_carry[2]

        _, h_tildes = jax.lax.scan(step, (h0, c0, feed0),
                                   (x_seq, jnp.arange(St)))
    else:
        # Phase A (both remaining engines): the hoisted embed-half NR
        # matmul, time-batched + compacted at (1-p) FLOPs, bias folded.
        gx0 = L.dense_sdrop_scheduled(
            {"w": dec[0]["W"], "b": dec[0]["b"]}, x_seq,
            ctx.schedule("dec/layer0/nr", St, B, cfg.embed))
        scheds = [ctx.schedule(n, St, B, H) for n in site_names]
        if cfg.engine == "fused":
            from repro.kernels import ops as _kops
            nr0 = ctx.spec("dec/layer0/nr")
            impl = next((s.spec.impl for s in scheds if not s.inactive),
                        nr0.impl if nr0.active else "xla")
            h_tildes, _ = _kops.decoder_scan(
                gx0, tuple(p["U"] for p in dec),
                tuple(p["W"] for p in dec[1:]),
                tuple(p["b"] for p in dec[1:]),
                params["w_feed"], params["w_comb"]["w"], enc_proj, enc_out,
                score_bias, h0, c0, feed0,
                sites=tuple(_site_args(s) for s in scheds), impl=impl,
                lengths=tgt_lengths)
        else:
            # scheduled: same restructure as a slim lax.scan. PER_STEP
            # mask rows ride through as xs, FIXED ones close over as
            # constants — no PRNG and no embed matmul in the body.
            xs_rows = tuple(s.scan_rows() for s in scheds)
            consts = [s.state(0) if r is None else None
                      for s, r in zip(scheds, xs_rows)]

            def step(carry, xs):
                gx0_t, rows, t = xs
                sts = [consts[i] if rows[i] is None
                       else scheds[i].state_for_row(rows[i])
                       for i in range(len(scheds))]
                new_carry, _ = _dec_step(params, nl, carry, gx0_t, sts,
                                         enc_proj, enc_out, score_bias)
                new_carry = freeze(new_carry, carry, t)
                return new_carry, new_carry[2]

            _, h_tildes = jax.lax.scan(step, (h0, c0, feed0),
                                       (gx0, xs_rows, jnp.arange(St)))
    # pass 2: time-batched output dropout + vocab projection.
    ht = h_tildes.transpose(1, 0, 2)                       # (B,St,H)
    ht = ctx.apply("dec/out", ht)
    return L.dense(params["fc"], ht).astype(jnp.float32)


def loss_fn(params, batch, cfg: NMTConfig, *, drop_key=None, rules=None,
            step=0, shard=None):
    """batch: {"src", "tgt_in", "tgt_out", ["src_mask", "tgt_mask",
    "src_lengths", "tgt_lengths"]}.

    Token-packed batches carry "src_lengths"/"tgt_lengths" (B,) int32
    instead of (or in addition to) the boolean masks: lengths freeze the
    recurrent carries inside both stacks (real FLOPs/grad savings, see
    kernels/cell_scan.py) and also derive the attention/loss masks when
    those aren't supplied explicitly.
    """
    ctx = cfg.plan.bind(drop_key, step, shard=shard)
    src_lengths = batch.get("src_lengths")
    tgt_lengths = batch.get("tgt_lengths")
    enc, st = encode(params, batch["src"], cfg, ctx=ctx,
                     lengths=src_lengths)
    src_mask = batch.get("src_mask")
    if src_mask is None and src_lengths is not None:
        src_mask = metrics.length_mask(src_lengths,
                                       batch["src"].shape[1]) > 0
    logits = decode_train(params, batch["tgt_in"], enc, st, cfg,
                          ctx=ctx, src_mask=src_mask,
                          tgt_lengths=tgt_lengths)
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, batch["tgt_out"][..., None], -1)[..., 0]
    mask = batch.get("tgt_mask")
    if mask is None and tgt_lengths is not None:
        mask = metrics.length_mask(tgt_lengths, batch["tgt_in"].shape[1])
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


# ---------------------------------------------------------------------------
# serving: free-running inference stays on the single-step path (the
# two-pass restructure needs all T inputs up front — teacher forcing).
# ---------------------------------------------------------------------------


def init_state(cfg: NMTConfig, batch: int, max_src: int):
    """Fresh decode state (every leaf batch-at-axis-1 for slot scatter).

    ``score_bias`` starts all -1e30: before prefill the softmax is uniform
    over zero encoder memory (finite, contributes nothing)."""
    nl, H = cfg.num_layers, cfg.hidden
    f32 = jnp.float32
    return {
        "h": jnp.zeros((nl, batch, H), f32),
        "c": jnp.zeros((nl, batch, H), f32),
        "feed": jnp.zeros((1, batch, H), f32),
        "enc_out": jnp.zeros((1, batch, max_src, H), f32),
        "enc_proj": jnp.zeros((1, batch, max_src, H), f32),
        "score_bias": jnp.full((1, batch, max_src), -1e30, f32),
    }


def _eval_step(params, nl, x_t, h, c, feed, enc_proj, enc_out, score_bias):
    """One no-dropout decoder step (the training step with eval states)."""
    dec = params["decoder"]
    gx0_t = L.dense_sdrop({"w": dec[0]["W"], "b": dec[0]["b"]}, x_t, None)
    (h, c, h_tilde), _ = _dec_step(params, nl, (h, c, feed), gx0_t,
                                   [None] * (2 * nl), enc_proj, enc_out,
                                   score_bias)
    return h, c, h_tilde


def prefill(params, batch, cfg: NMTConfig, state, *, rules=None):
    """Fill the decode state from {"src", "tgt_in", ["src_mask"]}: run the
    encoder, park its memory (enc_out / enc_proj / score_bias) in the
    state, then replay the target prefix through eval decoder steps so
    (h, c, feed) sit exactly where teacher-forced decoding left them."""
    del rules
    src = batch["src"]
    B, Ss = src.shape
    enc, enc_state = encode(params, src, cfg)              # eval ctx
    enc_proj = L.dense(params["w_att"], enc)
    src_mask = batch.get("src_mask")
    if src_mask is None:
        src_mask = jnp.ones((B, Ss), bool)
    sb = jnp.where(src_mask, 0.0, -1e30).astype(jnp.float32)
    state = dict(state)
    state["enc_out"] = state["enc_out"].at[0, :, :Ss, :].set(enc)
    state["enc_proj"] = state["enc_proj"].at[0, :, :Ss, :].set(enc_proj)
    state["score_bias"] = (jnp.full_like(state["score_bias"], -1e30)
                           .at[0, :, :Ss].set(sb))
    nl, H = cfg.num_layers, cfg.hidden
    ep, eo, sbf = state["enc_proj"][0], state["enc_out"][0], \
        state["score_bias"][0]
    x = jnp.take(params["tgt_embed"], batch["tgt_in"], axis=0)

    def step(carry, x_t):
        h, c, feed = carry
        h, c, h_tilde = _eval_step(params, nl, x_t, h, c, feed, ep, eo, sbf)
        return (h, c, h_tilde), None

    feed0 = jnp.zeros((B, H), enc.dtype)
    (h, c, feed), _ = jax.lax.scan(
        step, (enc_state.h, enc_state.c, feed0), x.transpose(1, 0, 2))
    state["h"], state["c"], state["feed"] = h, c, feed[None]
    return None, state


def decode_step(params, cfg: NMTConfig, state, tokens, pos, *, rules=None):
    """One serving decode step: tokens (B, 1) -> (logits (B, 1, V), state).
    ``pos`` is ignored — the recurrent state is O(1) in position."""
    del pos, rules
    x_t = jnp.take(params["tgt_embed"], tokens[:, 0], axis=0)
    h, c, h_tilde = _eval_step(
        params, cfg.num_layers, x_t, state["h"], state["c"],
        state["feed"][0], state["enc_proj"][0], state["enc_out"][0],
        state["score_bias"][0])
    logits = L.dense(params["fc"], h_tilde).astype(jnp.float32)[:, None]
    state = {**state, "h": h, "c": c, "feed": h_tilde[None]}
    return logits, state
