"""LSTM language models from the paper's Table 1.

Zaremba'14 medium (2x650, NR dropout .5) / large (2x1500, .65) and
AWD-LSTM (3x1150, embed 400, dropout vector [.4,.1,.25,.4] + recurrent .5).
The dropout *pattern* (Case I-IV, NR / NR+RH placement) is the experiment
variable — a ``DropoutPlan`` over the named sites

    "embed"  after the embedding lookup
    "nr"     non-recurrent input of every LSTM layer
    "rh"     recurrent hidden of every LSTM layer (the paper's extension)
    "out"    pre-FC output dropout

so benchmarks flip one knob (``cfg.plan``) while the model stays fixed.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core import lstm as lstm_mod
from repro.core import metrics
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec


@dataclasses.dataclass(frozen=True)
class LSTMLMConfig:
    name: str = "lstm_lm"
    vocab: int = 10000
    embed: int = 650
    hidden: int = 650
    num_layers: int = 2
    tie_embeddings: bool = False
    init_scale: float = 0.05
    plan: DropoutPlan = DropoutPlan()
    # recurrent execution engine: "scheduled" (two-phase: masks + NR matmuls
    # hoisted out of the scan), "fused" (Phase B as one persistent-scan
    # kernel per layer) or "stepwise" (in-scan reference)
    engine: str = "scheduled"
    param_dtype: Any = jnp.float32
    loss_chunks: int = 4


def _mk(defaults: dict, kw: dict) -> LSTMLMConfig:
    return LSTMLMConfig(**{**defaults, **kw})


def zaremba_medium(**kw) -> LSTMLMConfig:
    return _mk(dict(name="zaremba_medium", vocab=10000, embed=650, hidden=650,
                    num_layers=2, init_scale=0.05,
                    plan=DropoutPlan.case("case3", 0.5,
                                          sites=("embed", "nr", "out"))), kw)


def zaremba_large(**kw) -> LSTMLMConfig:
    return _mk(dict(name="zaremba_large", vocab=10000, embed=1500, hidden=1500,
                    num_layers=2, init_scale=0.04,
                    plan=DropoutPlan.case("case3", 0.65,
                                          sites=("embed", "nr", "out"))), kw)


def awd_lstm(**kw) -> LSTMLMConfig:
    return _mk(dict(name="awd_lstm", vocab=10000, embed=400, hidden=1150,
                    num_layers=3, tie_embeddings=True,
                    plan=DropoutPlan({"embed": DropoutSpec(rate=0.4),
                                      "nr": DropoutSpec(rate=0.25),
                                      "rh": DropoutSpec(rate=0.5),
                                      "out": DropoutSpec(rate=0.4)})), kw)


def init_params(key, cfg: LSTMLMConfig):
    k_e, k_l, k_f = jax.random.split(key, 3)
    p = {
        "embed": L.uniform_init(k_e, (cfg.vocab, cfg.embed), 0.1,
                                cfg.param_dtype),
        "lstm": lstm_mod.init_lstm_params(
            k_l, cfg.embed, cfg.hidden, cfg.num_layers,
            init_scale=cfg.init_scale, dtype=cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        p["fc"] = L.init_dense(k_f, cfg.hidden, cfg.vocab,
                               scale=cfg.init_scale, dtype=cfg.param_dtype)
    elif cfg.hidden != cfg.embed:
        p["proj"] = L.init_dense(k_f, cfg.hidden, cfg.embed, bias=False,
                                 dtype=cfg.param_dtype)
    return p


def forward(params, tokens, cfg: LSTMLMConfig, *, state=None, ctx=None,
            lengths=None):
    """tokens: (B, S) -> (logits (B,S,V), final state).

    ``lengths`` (B,) int32 marks a ragged batch: row b's recurrent carries
    freeze after its length (so the returned state carries over correctly
    in truncated-BPTT training) and frozen steps cost zero gradient.
    """
    if ctx is None:
        ctx = cfg.plan.bind(None)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)         # (B,S,E)
    x = ctx.apply("embed", x)
    if state is None:
        state = lstm_mod.zero_state(cfg.num_layers, B, cfg.hidden)
    ys, state = lstm_mod.lstm_stack(
        params["lstm"], x.transpose(1, 0, 2), state, ctx=ctx,
        engine=cfg.engine, lengths=lengths)
    h = ys.transpose(1, 0, 2)                              # (B,S,H)
    h = ctx.apply("out", h)
    if cfg.tie_embeddings:
        if "proj" in params:
            h = L.dense(params["proj"], h)
        logits = jnp.einsum("bsh,vh->bsv", h, params["embed"],
                            preferred_element_type=jnp.float32)
    else:
        logits = L.dense(params["fc"], h).astype(jnp.float32)
    return logits, state


def loss_fn(params, batch, cfg: LSTMLMConfig, *, state=None, drop_key=None,
            rules=None, step=0, shard=None):
    """Mean NLL per token — per *real* token when batch carries "lengths".

    ``shard`` (core.dropout_plan.BatchShard) marks this call as one batch
    shard of a data-parallel step: dense dropout masks are sampled at the
    global batch size and row-sliced so sharded grads match single-device.
    """
    ctx = cfg.plan.bind(drop_key, step, shard=shard)
    lengths = batch.get("lengths")
    logits, _ = forward(params, batch["tokens"], cfg, state=state, ctx=ctx,
                        lengths=lengths)
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, batch["labels"][..., None], axis=-1)
    if lengths is None:
        return nll.mean()
    mask = metrics.length_mask(lengths, batch["tokens"].shape[1])
    return metrics.masked_mean(nll[..., 0], mask)


def perplexity(params, tokens, labels, cfg: LSTMLMConfig,
               lengths=None) -> float:
    """exp(mean NLL) — over real tokens only when ``lengths`` is given."""
    logits, _ = forward(params, tokens, cfg, lengths=lengths)
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)
    if lengths is None:
        return float(jnp.exp(nll.mean()))
    mask = metrics.length_mask(lengths, tokens.shape[1])
    return float(jnp.exp(metrics.masked_mean(nll[..., 0], mask)))
