"""Model zoo: paper models (LSTM LM / NMT / NER) + assigned LM-family archs."""
