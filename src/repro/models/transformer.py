"""Config-driven transformer backbone for the assigned LM-family archs.

One implementation covers qwen3 / minitron / gemma / qwen1.5 (dense, GQA/MQA,
qk-norm, QKV-bias, GeGLU), mixtral / arctic (MoE top-k, SWA, dense-residual
MoE), pixtral (embeds-in backbone) and whisper (enc-dec, sinusoidal pos,
cross-attention) — each arch is a ``TransformerConfig``.

Structure:
  * stacked per-layer weights + ``lax.scan`` over layers (O(1) HLO in depth),
    ``jax.checkpoint`` remat per block;
  * chunked (flash-style online-softmax) attention — O(S·chunk) memory, with
    true FLOP reduction for sliding-window configs;
  * sort-based capacity-dropping MoE routing (static shapes, SPMD-friendly);
  * the paper's Case-III structured dropout on the *non-recurrent* direction:
    the (normalized) residual-stream input of each sub-layer is consumed
    through ``sdrop_matmul`` by the QKV / FFN-up projections, so FP/BP/WG all
    run at (1-p) FLOPs; masks are uniform across the batch*seq rows of the
    matmul and re-sampled per (layer, sub-layer, step).

Params are ``distributed.sharding.Param``-tagged with logical axes; use
``unzip`` to get (values, axes) and build NamedShardings for any mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import sparse_matmul as sm
from repro.core.dropout_plan import DropoutPlan, fit_block
from repro.distributed.sharding import tag, shard_act

# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    dense_ff: int = 0            # arctic: parallel dense-residual FFN width
    router_dtype: Any = jnp.float32
    # local routing (beyond-paper §Perf): sort/capacity per data shard
    # instead of globally. 1 = global (baseline). Set to the DP shard count
    # (pod x data) to eliminate the global-sort/scatter collectives; the
    # trade-off is per-shard (instead of global) capacity dropping.
    local_shards: int = 1


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "transformer"
    num_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 256
    vocab: int = 256
    mlp: str = "swiglu"          # swiglu | geglu | gelu_mlp
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    qk_norm: bool = False        # qwen3
    qkv_bias: bool = False       # qwen1.5
    pos: str = "rope"            # rope | sinusoidal | none
    rope_theta: float = 10000.0
    window: Optional[int] = None          # sliding-window attention (mixtral)
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = False
    scale_embed: bool = False    # gemma: embed * sqrt(d_model)
    max_seq: int = 4096          # positional table length (sinusoidal)
    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500          # audio-frame count (frontend stub)
    # frontend stub: inputs are precomputed embeddings, not token ids (pixtral)
    embeds_in: bool = False
    # numerics / execution
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    attn_impl: str = "xla"       # xla (chunked online-softmax) | flash (Pallas)
    q_chunk: int = 512
    kv_chunk: int = 512
    loss_chunks: int = 8
    remat: str = "full"          # full | dots | none
    # the paper's dropout pattern, over named sites: "nr" covers the
    # residual-stream inputs of both sub-layers (full site names "attn/nr",
    # "mlp/nr" keep the streams independent); "ffn_inner" is the
    # beyond-paper structured drop over the FFN inner dimension.
    plan: DropoutPlan = DropoutPlan()
    kv_repeat: int = 1           # replicate kv heads for TP shardability

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_kv_eff(self) -> int:
        return self.n_kv_heads * self.kv_repeat


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd), positions: (..., S) int32 — rotate pairs."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_table(max_len: int, dim: int) -> jax.Array:
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / dim))
    tab = jnp.zeros((max_len, dim))
    tab = tab.at[:, 0::2].set(jnp.sin(pos * div))
    tab = tab.at[:, 1::2].set(jnp.cos(pos * div))
    return tab


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_apply(kind, g, b, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * g).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * g + b).astype(x.dtype)


def init_norm(cfg, dim):
    p = {"g": tag(jnp.ones((dim,), cfg.param_dtype), "norm")}
    if cfg.norm == "layernorm":
        p["b"] = tag(jnp.zeros((dim,), cfg.param_dtype), "norm")
    return p


def _norm(cfg, p, x):
    return norm_apply(cfg.norm, p["g"], p.get("b"), x)


# ---------------------------------------------------------------------------
# Attention (chunked online-softmax; sliding window; GQA without kv repeat)
# ---------------------------------------------------------------------------


def _attn_chunk(q, k, v, qpos, kpos, *, causal, window, scale):
    """One (q-chunk x kv-chunk) tile. q: (B,cq,Hkv,G,hd); k,v: (B,ck,Hkv,hd).
    Returns (scores-exp sum l, running max m, weighted values o) pieces."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)                                  # (B,Hkv,G,cq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int],
                      q_chunk: int, kv_chunk: int,
                      q_offset: int = 0) -> jax.Array:
    """Flash-style attention. q: (B,Sq,Hq,hd); k,v: (B,Sk,Hkv,hd).

    Memory O(Sq·kv_chunk) per head; sliding-window configs slice a static
    (window + q_chunk) kv span per q chunk => true FLOP reduction.
    """
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = hd ** -0.5

    def _pick(S, c):  # largest divisor of S that is <= c
        c = min(c, S)
        while S % c:
            c -= 1
        return c

    cq, ck = _pick(Sq, q_chunk), _pick(Sk, kv_chunk)
    nq = Sq // cq
    qr = q.reshape(B, nq, cq, Hkv, G, hd)

    use_window = window is not None and window < Sk

    def per_q_chunk(qi, qc):
        qpos = q_offset + qi * cq + jnp.arange(cq)
        if use_window:
            # static kv span [qstart - window, qstart + cq)
            span = min(window + cq, Sk)
            start = jnp.clip(qi * cq + q_offset - window, 0, Sk - span)
            kw = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vw = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kpos = start + jnp.arange(span)
            m, l, o = _attn_chunk(qc, kw, vw, qpos, kpos,
                                  causal=causal, window=window, scale=scale)
            return m, l, o

        def kv_step(carry, inputs):
            m_a, l_a, o_a = carry
            kc, vc, kj = inputs
            kpos = kj * ck + jnp.arange(ck)
            m_c, l_c, o_c = _attn_chunk(qc, kc, vc, qpos, kpos,
                                        causal=causal, window=window,
                                        scale=scale)
            m_n = jnp.maximum(m_a, m_c)
            r_a = jnp.exp(m_a - m_n)
            r_c = jnp.exp(m_c - m_n)
            l_n = l_a * r_a + l_c * r_c
            o_n = o_a * r_a[..., None] + o_c * r_c[..., None]
            return (m_n, l_n, o_n), None

        m0 = jnp.full((B, Hkv, G, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        o0 = jnp.zeros((B, Hkv, G, cq, hd), jnp.float32)
        ks = k.reshape(B, Sk // ck, ck, Hkv, hd).transpose(1, 0, 2, 3, 4)
        vs = v.reshape(B, Sk // ck, ck, Hkv, hd).transpose(1, 0, 2, 3, 4)
        (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0),
                                    (ks, vs, jnp.arange(Sk // ck)))
        return m, l, o

    def q_step(_, inputs):
        qi, qc = inputs
        m, l, o = per_q_chunk(qi, qc)
        out = o / jnp.maximum(l[..., None], 1e-30)           # (B,Hkv,G,cq,hd)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, cq, Hq, hd)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None,
                           (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, Hq, hd)


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int]):
    """Single-token attention over a (B,Smax,Hkv,hd) cache. q: (B,1,Hq,hd)."""
    B, _, Hq, hd = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    idx = jnp.arange(Smax)
    mask = idx <= pos
    if window is not None:
        mask &= idx > pos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Parameter init (stacked layers, Param-tagged)
# ---------------------------------------------------------------------------


def _dense_init(key, shape, axes, cfg, scale=None):
    scale = scale if scale is not None else shape[0] ** -0.5
    w = jax.random.normal(key, shape, jnp.float32) * scale
    return tag(w.astype(cfg.param_dtype), *axes)


def init_block_params(key, cfg: TransformerConfig, num_layers: int,
                      cross_attn: bool = False):
    """Stacked (L, ...) block params."""
    D, H, KV, hd, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                       cfg.d_ff)
    L = num_layers
    ks = iter(jax.random.split(key, 32))
    p = {
        "ln1": {"g": tag(jnp.ones((L, D), cfg.param_dtype), "layer", "norm")},
        "ln2": {"g": tag(jnp.ones((L, D), cfg.param_dtype), "layer", "norm")},
        "wq": _dense_init(next(ks), (L, D, H * hd), ("layer", "embed", "heads"), cfg),
        "wk": _dense_init(next(ks), (L, D, KV * hd), ("layer", "embed", "kv_heads"), cfg),
        "wv": _dense_init(next(ks), (L, D, KV * hd), ("layer", "embed", "kv_heads"), cfg),
        "wo": _dense_init(next(ks), (L, H * hd, D), ("layer", "heads", "embed"), cfg),
    }
    if cfg.norm == "layernorm":
        p["ln1"]["b"] = tag(jnp.zeros((L, D), cfg.param_dtype), "layer", "norm")
        p["ln2"]["b"] = tag(jnp.zeros((L, D), cfg.param_dtype), "layer", "norm")
    if cfg.qkv_bias:
        p["bq"] = tag(jnp.zeros((L, H * hd), cfg.param_dtype), "layer", "heads")
        p["bk"] = tag(jnp.zeros((L, KV * hd), cfg.param_dtype), "layer", "kv_heads")
        p["bv"] = tag(jnp.zeros((L, KV * hd), cfg.param_dtype), "layer", "kv_heads")
    if cfg.qk_norm:
        p["qn"] = tag(jnp.ones((L, hd), cfg.param_dtype), "layer", "head_dim")
        p["kn"] = tag(jnp.ones((L, hd), cfg.param_dtype), "layer", "head_dim")
    if cross_attn:
        p["lnx"] = {"g": tag(jnp.ones((L, D), cfg.param_dtype), "layer", "norm")}
        if cfg.norm == "layernorm":
            p["lnx"]["b"] = tag(jnp.zeros((L, D), cfg.param_dtype), "layer", "norm")
        p["xq"] = _dense_init(next(ks), (L, D, H * hd), ("layer", "embed", "heads"), cfg)
        p["xk"] = _dense_init(next(ks), (L, D, KV * hd), ("layer", "embed", "kv_heads"), cfg)
        p["xv"] = _dense_init(next(ks), (L, D, KV * hd), ("layer", "embed", "kv_heads"), cfg)
        p["xo"] = _dense_init(next(ks), (L, H * hd, D), ("layer", "heads", "embed"), cfg)

    if cfg.moe is not None:
        E = cfg.moe.num_experts
        p["router"] = _dense_init(next(ks), (L, D, E), ("layer", "embed", "expert"), cfg)
        p["we_gate"] = _dense_init(next(ks), (L, E, D, F),
                                   ("layer", "expert", "embed", "expert_mlp"), cfg)
        p["we_up"] = _dense_init(next(ks), (L, E, D, F),
                                 ("layer", "expert", "embed", "expert_mlp"), cfg)
        p["we_down"] = _dense_init(next(ks), (L, E, F, D),
                                   ("layer", "expert", "expert_mlp", "embed"), cfg,
                                   scale=F ** -0.5)
        if cfg.moe.dense_ff:
            Fd = cfg.moe.dense_ff
            p["w_gate"] = _dense_init(next(ks), (L, D, Fd), ("layer", "embed", "mlp"), cfg)
            p["w_up"] = _dense_init(next(ks), (L, D, Fd), ("layer", "embed", "mlp"), cfg)
            p["w_down"] = _dense_init(next(ks), (L, Fd, D), ("layer", "mlp", "embed"),
                                      cfg, scale=Fd ** -0.5)
    else:
        if cfg.mlp in ("swiglu", "geglu"):
            p["w_gate"] = _dense_init(next(ks), (L, D, F), ("layer", "embed", "mlp"), cfg)
        p["w_up"] = _dense_init(next(ks), (L, D, F), ("layer", "embed", "mlp"), cfg)
        p["w_down"] = _dense_init(next(ks), (L, F, D), ("layer", "mlp", "embed"),
                                  cfg, scale=F ** -0.5)
    return p


def init_params(key, cfg: TransformerConfig):
    k_e, k_b, k_enc, k_h = jax.random.split(key, 4)
    p = {"blocks": init_block_params(k_b, cfg, cfg.num_layers,
                                     cross_attn=cfg.is_encoder_decoder),
         "ln_f": init_norm(cfg, cfg.d_model)}
    if not cfg.embeds_in:
        p["embed"] = tag(
            (jax.random.normal(k_e, (cfg.vocab, cfg.d_model)) * 0.02
             ).astype(cfg.param_dtype), "vocab", "embed")
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense_init(k_h, (cfg.d_model, cfg.vocab),
                                   ("embed", "vocab"), cfg)
    if cfg.is_encoder_decoder:
        p["enc_blocks"] = init_block_params(k_enc, cfg, cfg.enc_layers)
        p["enc_ln_f"] = init_norm(cfg, cfg.d_model)
    return p


# ---------------------------------------------------------------------------
# MoE: sort-based capacity routing (static shapes)
# ---------------------------------------------------------------------------


def moe_ffn(pl, x2d, cfg: TransformerConfig, rules):
    """x2d: (T, D) -> (T, D). Sort-by-expert, capacity-drop, grouped matmul.

    With ``local_shards = S > 1`` the routing (sort / capacity / scatter /
    gather) is vectorized over a leading shard dim that is data-sharded:
    every routing op acts row-wise, so the SPMD partitioner keeps it fully
    local — the global sort/scatter collectives of S=1 disappear, at the
    cost of per-shard (instead of global) capacity dropping.
    """
    mcfg = cfg.moe
    T, D = x2d.shape
    E, K = mcfg.num_experts, mcfg.top_k
    S = mcfg.local_shards if T % max(mcfg.local_shards, 1) == 0 else 1
    S = max(S, 1)
    Tl = T // S
    C = max(1, int(math.ceil(Tl * K / E * mcfg.capacity_factor)))

    x3 = x2d.reshape(S, Tl, D)
    x3 = shard_act(x3, ("batch", None, "embed_act"), rules)

    logits = jnp.einsum("std,de->ste", x3.astype(mcfg.router_dtype),
                        pl["router"].astype(mcfg.router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (S, Tl, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    flat_e = expert_idx.reshape(S, Tl * K)
    order = jnp.argsort(flat_e, axis=-1)                     # per-shard sort
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # position of each token within its expert group (per shard)
    first = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(
        sorted_e)
    pos_in_e = jnp.arange(Tl * K)[None] - first
    valid = pos_in_e < C
    dest = jnp.where(valid, sorted_e * C + pos_in_e, E * C)  # drop -> scratch

    tok_idx = order // K                                     # (S, Tl*K)
    xs = jnp.take_along_axis(x3, tok_idx[..., None], axis=1)
    buf = jax.vmap(lambda d_, xs_: jnp.zeros((E * C + 1, D), x2d.dtype)
                   .at[d_].set(xs_)[:-1])(dest, xs)
    buf = buf.reshape(S, E, C, D)
    buf = shard_act(buf, ("batch", "expert", "cap", "embed_act"), rules)

    # grouped FFN (per-expert swiglu); expert_mlp dim is tensor-parallel
    g = jnp.einsum("secd,edf->secf", buf, pl["we_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("secd,edf->secf", buf, pl["we_up"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x2d.dtype)
    y_e = jnp.einsum("secf,efd->secd", h, pl["we_down"],
                     preferred_element_type=jnp.float32).astype(x2d.dtype)
    y_e = shard_act(y_e, ("batch", "expert", "cap", "embed_act"), rules)

    # gather back, un-sort, combine top-k with gate weights
    y_flat2 = y_e.reshape(S, E * C, D)
    y_sorted = jnp.take_along_axis(
        y_flat2, jnp.minimum(dest, E * C - 1)[..., None], axis=1) \
        * valid[..., None]
    inv = jnp.argsort(order, axis=-1)
    y_unsorted = jnp.take_along_axis(y_sorted, inv[..., None], axis=1)
    y = (y_unsorted.reshape(S, Tl, K, D)
         * gate_vals[..., None].astype(x2d.dtype)).sum(axis=2)
    return y.reshape(T, D)


# ---------------------------------------------------------------------------
# Block (attention + mlp/moe) — operates on one layer's params
# ---------------------------------------------------------------------------


def _proj_sdrop(x, w, b, drop_state):
    """Projection consuming x through NR structured dropout (paper FP/BP/WG)."""
    if drop_state is None or drop_state.inactive:
        y = jnp.einsum("bsd,dn->bsn", x, w,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    elif drop_state.structured:
        y = sm.sdrop_matmul(x, w, drop_state.keep_blocks,
                            rate=drop_state.spec.rate,
                            block_size=drop_state.spec.block_size,
                            impl=drop_state.spec.impl,
                            scale=drop_state.scale)
    else:  # Case-I/II baseline: mask-multiply, dense matmul
        xm = drop_state.apply(x)
        y = jnp.einsum("bsd,dn->bsn", xm, w,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    return y + b if b is not None else y


def _mlp(pl, h, cfg, drop_state, rules):
    """Dense FFN with NR sdrop on input; optional FFN-inner structured drop."""
    inner = drop_state.inner_spec if drop_state is not None else None
    if inner is not None and drop_state.inner_kb is not None:
        kb, scale = drop_state.inner_kb, drop_state.inner_scale
        bs = inner.block_size
        up = sm.sdrop_matmul_out(h, pl["w_up"], kb, rate=inner.rate, block_size=bs)
        if cfg.mlp in ("swiglu", "geglu"):
            gt = sm.sdrop_matmul_out(h, pl["w_gate"], kb, rate=inner.rate, block_size=bs)
            act = jax.nn.silu(gt) * up if cfg.mlp == "swiglu" else jax.nn.gelu(gt) * up
        elif cfg.mlp == "relu2":
            act = jnp.square(jax.nn.relu(up))
        else:
            act = jax.nn.gelu(up)
        return sm.sdrop_matmul(act, pl["w_down"], kb, rate=inner.rate,
                               block_size=bs, x_is_compact=True, scale=scale)
    up = _proj_sdrop(h, pl["w_up"], None, drop_state)
    if cfg.mlp in ("swiglu", "geglu"):
        gt = _proj_sdrop(h, pl["w_gate"], None, drop_state)
        act = jax.nn.silu(gt) * up if cfg.mlp == "swiglu" else jax.nn.gelu(gt) * up
    elif cfg.mlp == "relu2":
        act = jnp.square(jax.nn.relu(up))
    else:
        act = jax.nn.gelu(up)
    y = jnp.einsum("bsf,fd->bsd", act, pl["w_down"],
                   preferred_element_type=jnp.float32).astype(h.dtype)
    return y


def _qkv(pl, h, cfg, drop_state, positions, prefix=""):
    B, S, _ = h.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    wq, wk, wv = pl[prefix + ("q" if prefix else "wq")], \
        pl[prefix + ("k" if prefix else "wk")], \
        pl[prefix + ("v" if prefix else "wv")]
    bq = pl.get("bq") if not prefix else None
    bk = pl.get("bk") if not prefix else None
    bv = pl.get("bv") if not prefix else None
    q = _proj_sdrop(h, wq, bq, drop_state).reshape(B, S, H, hd)
    k = _proj_sdrop(h, wk, bk, drop_state).reshape(B, S, KV, hd)
    v = _proj_sdrop(h, wv, bv, drop_state).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = norm_apply("rmsnorm", pl["qn"], None, q)
        k = norm_apply("rmsnorm", pl["kn"], None, k)
    if cfg.pos == "rope" and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.kv_repeat > 1:
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)
    return q, k, v


def block_apply(pl, x, cfg: TransformerConfig, *, causal: bool,
                drop_states=(None, None), positions=None, rules=None,
                memory=None, cache=None, cache_pos=None):
    """One transformer block. Returns (x, new_cache_entry_or_None).

    cache: {"k": (B,Smax,KVeff,hd), "v": ...} for decode (S==1 path).
    memory: (B, T_enc, D) encoder output for cross-attention layers.
    """
    B, S, D = x.shape
    d_attn, d_mlp = drop_states
    new_cache = None

    h = _norm(cfg, pl["ln1"], x)
    q, k, v = _qkv(pl, h, cfg, d_attn, positions)

    def _attend(q, k, v):
        if cfg.attn_impl == "flash":
            from repro.kernels.flash_attention import flash_attention
            return flash_attention(q, k, v, causal, cfg.window,
                                   cfg.q_chunk, cfg.kv_chunk)
        if cfg.attn_impl == "identity":
            # roofline instrumentation only: no mixing — isolates the
            # attention contribution to the memory term (see §Perf).
            G = q.shape[2] // k.shape[2]
            return q * jnp.repeat(v, G, axis=2)
        return chunked_attention(q, k, v, causal=causal, window=cfg.window,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)

    if cache is not None:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_pos, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_pos, 1)
        new_cache = {"k": k_cache, "v": v_cache}
        if S == 1:
            attn = decode_attention(q, k_cache, v_cache, cache_pos,
                                    window=cfg.window)
        else:  # prefill: attend within the freshly written span
            attn = _attend(q, k, v)
    else:
        attn = _attend(q, k, v)
    attn = attn.reshape(B, S, cfg.n_heads * cfg.hd)
    x = x + jnp.einsum("bsn,nd->bsd", attn, pl["wo"],
                       preferred_element_type=jnp.float32).astype(x.dtype)

    if memory is not None and "xq" in pl:
        hx = _norm(cfg, pl["lnx"], x)
        qx, kx, vx = _qkv({"xq": pl["xq"], "xk": pl["xk"], "xv": pl["xv"]},
                          hx, cfg, None, None, prefix="x")
        ax = chunked_attention(qx, kx, vx, causal=False, window=None,
                               q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        ax = ax.reshape(B, S, cfg.n_heads * cfg.hd)
        x = x + jnp.einsum("bsn,nd->bsd", ax, pl["xo"],
                           preferred_element_type=jnp.float32).astype(x.dtype)

    h2 = _norm(cfg, pl["ln2"], x)
    if cfg.moe is not None:
        y2d = moe_ffn(pl, h2.reshape(B * S, D), cfg, rules).reshape(B, S, D)
        if cfg.moe.dense_ff:
            y2d = y2d + _mlp(pl, h2, cfg, d_mlp, rules)
        x = x + y2d
    else:
        x = x + _mlp(pl, h2, cfg, d_mlp, rules)
    x = shard_act(x, ("batch", "seq", "embed_act"), rules)
    return x, new_cache


# ---------------------------------------------------------------------------
# Dropout-state plumbing (per layer, per sub-layer, per step)
# ---------------------------------------------------------------------------


def _layer_drop_states(ctx, cfg: TransformerConfig, layer_idx, bs_shape,
                       prefix=""):
    """Two NR DropoutStates (attention-in, mlp-in) + optional FFN-inner ids.

    bs_shape = (B, S): the random (Case-I/II) baseline samples a per-token
    mask of that shape; structured cases sample kept-block ids over d_model.
    The layer index is this arch's time axis: PER_STEP specs re-sample per
    layer, FIXED specs share one mask across the depth scan. ``prefix``
    separates the encoder stack's streams ("enc/") from the decoder's.
    """
    from repro.core import masks as _m
    if ctx is None or ctx.deterministic:
        return (None, None)
    inner = fit_block(ctx.spec(prefix + "mlp/ffn_inner"), cfg.d_ff)
    if not (ctx.spec(prefix + "attn/nr").active
            or ctx.spec(prefix + "mlp/nr").active or inner.structured):
        return (None, None)
    st_a = ctx.state(prefix + "attn/nr", bs_shape, cfg.d_model, t=layer_idx)
    st_m = ctx.state(prefix + "mlp/nr", bs_shape, cfg.d_model, t=layer_idx)
    if inner.structured and cfg.moe is None:
        ki = ctx.site_key(prefix + "mlp/ffn_inner", t=layer_idx)
        st_m.inner_kb = _m.sample_keep_blocks(
            ki, cfg.d_ff, inner.rate, inner.block_size)
        st_m.inner_scale = _m.inverted_scale(
            inner.rate, cfg.d_ff, inner.block_size)
        st_m.inner_spec = inner
    return (st_a, st_m)


# ---------------------------------------------------------------------------
# Full model: forward / loss / prefill / decode
# ---------------------------------------------------------------------------


def _remat(f, cfg):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f)


def _embed_tokens(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)
    return x


def _run_stack(blocks, x, cfg, *, causal, positions, rules, ctx=None,
               site_prefix="", memory=None, num_layers=None):
    """scan over stacked layer params; remat per block."""
    L = num_layers or cfg.num_layers

    def body(x, inp):
        pl, li = inp
        ds = _layer_drop_states(ctx, cfg, li, x.shape[:2], prefix=site_prefix)
        y, _ = block_apply(pl, x, cfg, causal=causal, drop_states=ds,
                           positions=positions, rules=rules, memory=memory)
        return y, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, (blocks, jnp.arange(L)))
    return x


def encode(params, frames, cfg: TransformerConfig, rules=None, ctx=None):
    """Whisper encoder: frames (B, T_enc, D) from the conv-frontend stub."""
    pos = sinusoidal_table(frames.shape[1], cfg.d_model).astype(cfg.compute_dtype)
    x = frames.astype(cfg.compute_dtype) + pos[None]
    x = _run_stack(params["enc_blocks"], x, cfg, causal=False, positions=None,
                   rules=rules, ctx=ctx, site_prefix="enc/",
                   num_layers=cfg.enc_layers)
    return _norm(cfg, params["enc_ln_f"], x)


def forward(params, inputs, cfg: TransformerConfig, *, rules=None,
            ctx=None, memory=None):
    """Token/embeds -> final-norm features (B, S, D)."""
    if cfg.embeds_in:
        x = inputs.astype(cfg.compute_dtype)
    else:
        x = _embed_tokens(params, inputs, cfg)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None].repeat(B, 0)
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_table(S, cfg.d_model).astype(x.dtype)[None]
        positions = None
    x = shard_act(x, ("batch", "seq", "embed_act"), rules)
    x = _run_stack(params["blocks"], x, cfg, causal=True, positions=positions,
                   rules=rules, ctx=ctx, memory=memory)
    return _norm(cfg, params["ln_f"], x)


def lm_logits(params, feats, cfg):
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return jnp.einsum("bsd,dv->bsv", feats, w,
                      preferred_element_type=jnp.float32)


def lm_loss(params, feats, labels, cfg: TransformerConfig, rules=None):
    """Chunked softmax-xent over the sequence: live logits = S/loss_chunks."""
    B, S, D = feats.shape
    n = cfg.loss_chunks
    while S % n:
        n -= 1
    fs = feats.reshape(B, n, S // n, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, S // n).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk(carry, inp):
        f, l = inp
        logits = lm_logits(params, f, cfg)
        logits = shard_act(logits, ("batch", "seq", "vocab"), rules)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, l[..., None], axis=-1).squeeze(-1)
        return carry + nll.sum(), None

    total, _ = jax.lax.scan(chunk, jnp.zeros((), jnp.float32), (fs, ls))
    return total / (B * S)


def loss_fn(params, batch, cfg: TransformerConfig, *, rules=None,
            drop_key=None, step=0):
    """Training loss. batch: {"tokens" | "embeds", "labels", ["frames"]}."""
    ctx = cfg.plan.bind(drop_key, step)
    memory = None
    if cfg.is_encoder_decoder:
        memory = encode(params, batch["frames"], cfg, rules=rules, ctx=ctx)
    inputs = batch["embeds"] if cfg.embeds_in else batch["tokens"]
    feats = forward(params, inputs, cfg, rules=rules, ctx=ctx,
                    memory=memory)
    return lm_loss(params, feats, batch["labels"], cfg, rules=rules)


# -------------------------- serving ---------------------------------------


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=None):
    """KV cache pytree: stacked (L, B, Smax, KVeff, hd) + cross-KV if enc-dec."""
    dtype = dtype or cfg.compute_dtype
    L, KV, hd = cfg.num_layers, cfg.n_kv_eff, cfg.hd
    c = {"k": jnp.zeros((L, batch, max_seq, KV, hd), dtype),
         "v": jnp.zeros((L, batch, max_seq, KV, hd), dtype)}
    if cfg.is_encoder_decoder:
        c["xk"] = jnp.zeros((L, batch, cfg.enc_seq, KV, hd), dtype)
        c["xv"] = jnp.zeros((L, batch, cfg.enc_seq, KV, hd), dtype)
    return c


def cache_axes():
    return ("layer", "batch", "kv_seq", "kv_heads", "head_dim")


def prefill(params, tokens_or_embeds, cfg: TransformerConfig, cache, *,
            rules=None, memory=None):
    """Forward pass that also fills the KV cache; returns (feats, cache)."""
    if cfg.embeds_in:
        x = tokens_or_embeds.astype(cfg.compute_dtype)
    else:
        x = _embed_tokens(params, tokens_or_embeds, cfg)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None].repeat(B, 0)
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_table(S, cfg.d_model).astype(x.dtype)[None]
        positions = None
    x = shard_act(x, ("batch", "seq", "embed_act"), rules)

    if memory is not None:
        # Precompute cross K/V into the cache (whisper decode path).
        def xkv(carry, pl):
            kx = jnp.einsum("btd,dn->btn", memory, pl["xk"]).reshape(
                B, -1, cfg.n_kv_heads, cfg.hd)
            vx = jnp.einsum("btd,dn->btn", memory, pl["xv"]).reshape(
                B, -1, cfg.n_kv_heads, cfg.hd)
            if cfg.kv_repeat > 1:
                kx = jnp.repeat(kx, cfg.kv_repeat, axis=2)
                vx = jnp.repeat(vx, cfg.kv_repeat, axis=2)
            return carry, (kx, vx)

        _, (xk, xv) = jax.lax.scan(xkv, None, params["blocks"])
        cache = {**cache, "xk": xk.astype(cache["xk"].dtype),
                 "xv": xv.astype(cache["xv"].dtype)}

    def body(x, inp):
        pl, entry = inp
        y, new_entry = block_apply(
            pl, x, cfg, causal=True, positions=positions, rules=rules,
            memory=memory, cache={"k": entry["k"], "v": entry["v"]},
            cache_pos=0)
        return y, new_entry

    entries = {"k": cache["k"], "v": cache["v"]}
    x, new_entries = jax.lax.scan(_remat(body, cfg), x,
                                  (params["blocks"], entries))
    cache = {**cache, **new_entries}
    return _norm(cfg, params["ln_f"], x), cache


def decode_step(params, cfg: TransformerConfig, cache, tokens, pos, *,
                rules=None):
    """One decode step. tokens: (B, 1) int32 (or (B,1,D) embeds); pos scalar.

    Returns (logits (B,1,V) fp32, updated cache)."""
    if cfg.embeds_in:
        x = tokens.astype(cfg.compute_dtype)
    else:
        x = _embed_tokens(params, tokens, cfg)
    B = x.shape[0]
    if cfg.pos == "sinusoidal":
        x = x + jax.lax.dynamic_slice_in_dim(
            sinusoidal_table(cfg.max_seq, cfg.d_model).astype(x.dtype),
            pos, 1, axis=0)[None]
        positions = None
    else:
        positions = jnp.full((B, 1), pos, jnp.int32)

    def body(x, inp):
        pl, entry = inp
        mem_kv = None
        if cfg.is_encoder_decoder:
            mem_kv = (entry["xk"], entry["xv"])
        y, new_entry = _decode_block(pl, x, cfg, entry, pos, positions,
                                     rules, mem_kv)
        return y, new_entry

    x, new_entries = jax.lax.scan(body, x, (params["blocks"], cache))
    x = _norm(cfg, params["ln_f"], x)
    logits = lm_logits(params, x, cfg)
    logits = shard_act(logits, ("batch", "seq", "vocab"), rules)
    return logits, new_entries


def _decode_block(pl, x, cfg, entry, pos, positions, rules, mem_kv):
    B = x.shape[0]
    h = _norm(cfg, pl["ln1"], x)
    q, k, v = _qkv(pl, h, cfg, None, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(entry["k"], k, pos, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(entry["v"], v, pos, 1)
    attn = decode_attention(q, k_cache, v_cache, pos, window=cfg.window)
    attn = attn.reshape(B, 1, cfg.n_heads * cfg.hd)
    x = x + jnp.einsum("bsn,nd->bsd", attn, pl["wo"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    if mem_kv is not None:
        hx = _norm(cfg, pl["lnx"], x)
        qx = jnp.einsum("bsd,dn->bsn", hx, pl["xq"]).reshape(
            B, 1, cfg.n_heads, cfg.hd)
        xk, xv = mem_kv
        ax = decode_attention(qx, xk, xv, xk.shape[1] - 1, window=None)
        ax = ax.reshape(B, 1, cfg.n_heads * cfg.hd)
        x = x + jnp.einsum("bsn,nd->bsd", ax, pl["xo"],
                           preferred_element_type=jnp.float32).astype(x.dtype)
    h2 = _norm(cfg, pl["ln2"], x)
    if cfg.moe is not None:
        y = moe_ffn(pl, h2.reshape(B, -1), cfg, rules).reshape(B, 1, -1)
        if cfg.moe.dense_ff:
            y = y + _mlp(pl, h2, cfg, None, rules)
        x = x + y
    else:
        x = x + _mlp(pl, h2, cfg, None, rules)
    new_entry = {**entry, "k": k_cache, "v": v_cache}
    return x, new_entry
