"""BiLSTM-CNN-CRF sequence tagger (Ma & Hovy 2016; paper Table 3).

Char-CNN word encoding + word embeddings -> concat -> (structured) dropout
-> BiLSTM (forward + backward stacks, both with the paper's NR+RH structured
dropout) -> linear-chain CRF (forward-algorithm loss + Viterbi decode).

Per the paper §4.3 we move the dropout from the CNN *input* to the
*concatenated* CNN+embedding output, raising exploitable input sparsity to
the full dropout rate.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core import lstm as lstm_mod
from repro.core import metrics
from repro.core.dropout_plan import DropoutPlan
from repro.core.sdrop import DropoutSpec


@dataclasses.dataclass(frozen=True)
class TaggerConfig:
    name: str = "bilstm_crf"
    vocab: int = 20000
    char_vocab: int = 100
    char_embed: int = 30
    char_filters: int = 30
    char_kernel: int = 3
    word_embed: int = 100
    hidden: int = 200
    num_tags: int = 9
    # sites: "inp" on concat(CNN, embed); "rh" recurrent (paper extension)
    plan: DropoutPlan = DropoutPlan({"inp": DropoutSpec(rate=0.5)})
    engine: str = "scheduled"      # recurrent engine (core.lstm.lstm_stack)
    param_dtype: Any = jnp.float32


def init_params(key, cfg: TaggerConfig):
    ks = jax.random.split(key, 8)
    feat = cfg.word_embed + cfg.char_filters
    return {
        "word_embed": L.uniform_init(ks[0], (cfg.vocab, cfg.word_embed), 0.1),
        "char_embed": L.uniform_init(ks[1], (cfg.char_vocab, cfg.char_embed), 0.1),
        "char_conv": {
            "w": L.uniform_init(ks[2], (cfg.char_kernel, cfg.char_embed,
                                        cfg.char_filters),
                                (cfg.char_kernel * cfg.char_embed) ** -0.5),
            "b": jnp.zeros((cfg.char_filters,)),
        },
        "fwd": lstm_mod.init_lstm_params(ks[3], feat, cfg.hidden, 1),
        "bwd": lstm_mod.init_lstm_params(ks[4], feat, cfg.hidden, 1),
        "fc": L.init_dense(ks[5], 2 * cfg.hidden, cfg.num_tags),
        "crf": L.uniform_init(ks[6], (cfg.num_tags, cfg.num_tags), 0.1),
    }


def char_cnn(params, chars, cfg: TaggerConfig):
    """chars: (B, S, W) char ids -> (B, S, F) via conv + max-pool over W."""
    B, S, W = chars.shape
    x = jnp.take(params["char_embed"], chars, axis=0)      # (B,S,W,E)
    K = cfg.char_kernel
    xp = jnp.pad(x, ((0, 0), (0, 0), (K // 2, K - 1 - K // 2), (0, 0)))
    w, b = params["char_conv"]["w"], params["char_conv"]["b"]
    conv = sum(jnp.einsum("bswe,ef->bswf", xp[:, :, i:i + W, :], w[i])
               for i in range(K)) + b
    return jnp.max(jax.nn.relu(conv), axis=2)              # (B,S,F)


def _reverse_valid(xs, lengths):
    """Per-row reversal of each row's valid prefix. xs: (S, B, D).

    Position t maps to ``lengths[b] - 1 - t`` for t < lengths[b] and stays
    put on the padded tail, so a ragged batch's backward LSTM reads real
    tokens first exactly as an unpacked per-row reversal would.
    """
    S = xs.shape[0]
    t = jnp.arange(S)[:, None]
    idx = jnp.where(t < lengths[None, :], lengths[None, :] - 1 - t, t)
    return jnp.take_along_axis(xs, idx[:, :, None], axis=0)


def features(params, batch, cfg: TaggerConfig, *, ctx=None):
    """-> (B, S, 2H) BiLSTM features.

    When the batch carries "lengths" (B,) int32 the rows are ragged: both
    direction stacks freeze their carries past each row's length, and the
    backward stack reverses only the valid prefix (pads never enter it).
    """
    if ctx is None:
        ctx = cfg.plan.bind(None)
    words, chars = batch["words"], batch["chars"]
    lengths = batch.get("lengths")
    B, S = words.shape
    we = jnp.take(params["word_embed"], words, axis=0)
    ce = char_cnn(params, chars, cfg)
    x = jnp.concatenate([we, ce], axis=-1)                 # (B,S,feat)

    # paper §4.3: structured dropout on the concatenated features
    x = ctx.apply("inp", x)

    def run(dirn, xs):
        state = lstm_mod.zero_state(1, B, cfg.hidden)
        # site prefix = direction -> independent fwd/bwd RH streams
        ys, _ = lstm_mod.lstm_stack(params[dirn], xs, state, ctx=ctx,
                                    site=dirn, engine=cfg.engine,
                                    lengths=lengths)
        return ys

    xs = x.transpose(1, 0, 2)                              # (S,B,feat)
    fwd = run("fwd", xs)
    if lengths is None:
        bwd = run("bwd", xs[::-1])[::-1]
    else:
        bwd = _reverse_valid(run("bwd", _reverse_valid(xs, lengths)),
                             lengths)
    h = jnp.concatenate([fwd, bwd], axis=-1).transpose(1, 0, 2)
    return h


def emissions(params, batch, cfg: TaggerConfig, *, ctx=None):
    return L.dense(params["fc"], features(params, batch, cfg, ctx=ctx))


def crf_log_norm(emit, trans, mask):
    """Forward algorithm. emit: (B,S,T); trans: (T,T); mask: (B,S)."""
    def step(alpha, inp):
        e_t, m_t = inp                                     # (B,T), (B,)
        scores = alpha[:, :, None] + trans[None] + e_t[:, None, :]
        new = jax.nn.logsumexp(scores, axis=1)
        alpha = jnp.where(m_t[:, None], new, alpha)
        return alpha, None

    alpha0 = emit[:, 0]
    alpha, _ = jax.lax.scan(step, alpha0,
                            (emit[:, 1:].transpose(1, 0, 2),
                             mask[:, 1:].transpose(1, 0)))
    return jax.nn.logsumexp(alpha, axis=-1)                # (B,)


def crf_score(emit, tags, trans, mask):
    """Score of a given tag sequence."""
    B, S, Tg = emit.shape
    e = jnp.take_along_axis(emit, tags[..., None], axis=-1)[..., 0]  # (B,S)
    e = (e * mask).sum(-1)
    t_scores = trans[tags[:, :-1], tags[:, 1:]]            # (B,S-1)
    t = (t_scores * mask[:, 1:]).sum(-1)
    return e + t


def loss_fn(params, batch, cfg: TaggerConfig, *, drop_key=None, rules=None,
            step=0, shard=None):
    ctx = cfg.plan.bind(drop_key, step, shard=shard)
    emit = emissions(params, batch, cfg, ctx=ctx)
    mask = batch.get("mask")
    if mask is None:
        lmask = metrics.resolve_mask(batch, batch["words"])
        mask = (lmask > 0 if lmask is not None
                else jnp.ones(batch["words"].shape, bool))
    logZ = crf_log_norm(emit, params["crf"], mask)
    score = crf_score(emit, batch["tags"], params["crf"], mask)
    if "lengths" in batch:
        # dummy rows (length 0) must not dilute the per-sequence mean
        real = (batch["lengths"] > 0).astype(jnp.float32)
        return ((logZ - score) * real).sum() / jnp.maximum(real.sum(), 1.0)
    return (logZ - score).mean()


def viterbi(params, batch, cfg: TaggerConfig):
    """Most-likely tag sequence. Returns (B, S) int32."""
    emit = emissions(params, batch, cfg)
    trans = params["crf"]
    B, S, Tg = emit.shape

    def step(alpha, e_t):
        scores = alpha[:, :, None] + trans[None]
        best = jnp.argmax(scores, axis=1)                  # (B,T)
        alpha = jnp.max(scores, axis=1) + e_t
        return alpha, best

    alpha, back = jax.lax.scan(step, emit[:, 0], emit[:, 1:].transpose(1, 0, 2))
    last = jnp.argmax(alpha, axis=-1)                      # (B,)

    def bt(tag, bp):
        prev = jnp.take_along_axis(bp, tag[:, None], axis=1)[:, 0]
        return prev, prev

    _, tags_rev = jax.lax.scan(bt, last, back[::-1])
    tags = jnp.concatenate([tags_rev[::-1], last[None]], axis=0)
    return tags.transpose(1, 0)
