"""Mamba2 (SSD) + the zamba2 hybrid (Mamba2 backbone, shared attention block).

Mamba2's state-space recurrence is *linear* — there is no hidden-to-hidden
weight matmul — so the paper's RH direction does not apply to the SSM core
(noted in DESIGN §Arch-applicability). The NR direction does: the block
input projection consumes the residual stream through structured dropout.

The SSD scan uses the chunkwise (segsum) form from the Mamba2 paper:
quadratic attention-with-decay inside chunks (MXU matmuls) + a recurrent
state pass across chunks. Decode is the O(1)-per-token recurrent step, which
is what makes the 500k-token long-context cell runnable.

zamba2: stacked Mamba2 blocks; ONE shared transformer block (attention+MLP,
one set of weights) is applied every ``shared_every`` blocks on
``concat(hidden, residual-stream input)`` — following Zamba's weight-shared
global-attention design (arXiv:2411.15242).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import sparse_matmul as sm
from repro.core.dropout_plan import DropoutPlan
from repro.distributed.sharding import tag, shard_act
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    name: str = "mamba2"
    num_layers: int = 4
    d_model: int = 128
    ssm_state: int = 64          # N
    n_heads: int = 8             # SSD heads; head dim P = inner / n_heads
    expand: int = 2              # inner = expand * d_model
    conv_kernel: int = 4
    chunk: int = 64
    vocab: int = 256
    # zamba2 hybrid: shared attention block
    shared_attn: bool = False
    shared_every: int = 6
    attn_heads: int = 8
    attn_kv_heads: int = 8
    attn_ff: int = 0             # shared block MLP width (0 = 4*d_model)
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    loss_chunks: int = 8
    remat: str = "full"
    # dropout pattern over the "nr" site (block input projection; the SSM
    # core has no h-to-h weight, so RH does not apply — DESIGN §Arch-applic.)
    plan: DropoutPlan = DropoutPlan()

    @property
    def inner(self) -> int:
        return self.expand * self.d_model

    @property
    def head_p(self) -> int:
        return self.inner // self.n_heads


# ---------------------------------------------------------------------------
# SSD chunkwise scan
# ---------------------------------------------------------------------------


def _segsum(a):
    """log-space segment sums: out[..., t, s] = sum_{s < tau <= t} a[..., tau].

    a: (..., c). Returns (..., c, c), -inf above the diagonal.
    """
    c = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, chunk: int, initial=None):
    """Chunkwise SSD (Mamba2 alg. 1).

    x: (b, S, H, P); dt: (b, S, H) (post-softplus); A: (H,) negative;
    B, C: (b, S, G, N); D: (H,) skip. Returns (y (b,S,H,P), final_state
    (b, H, P, N)).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    rep = H // G

    # discretize
    xd = x * dt[..., None]                      # dt-weighted input
    da = dt * A                                 # (b,S,H) log-decay per step

    xc = xd.reshape(b, nc, c, H, P).transpose(1, 0, 2, 3, 4)
    dac = da.reshape(b, nc, c, H).transpose(1, 0, 2, 3)
    Bc = B.reshape(b, nc, c, G, N).transpose(1, 0, 2, 3, 4)
    Cc = C.reshape(b, nc, c, G, N).transpose(1, 0, 2, 3, 4)

    if initial is None:
        S0 = jnp.zeros((b, H, P, N), jnp.float32)
    else:
        S0 = initial

    def chunk_step(Sst, inp):
        xx, aa, BB, CC = inp                     # (b,c,H,P),(b,c,H),(b,c,G,N)
        a_t = aa.transpose(0, 2, 1)              # (b,H,c)
        Lmat = jnp.exp(_segsum(a_t))             # (b,H,c,c) decay, lower-tri
        # intra-chunk: y = (C B^T ⊙ L) x
        CB = jnp.einsum("bthn,bshn->bhts",
                        CC.repeat(rep, 2) if rep > 1 else CC,
                        BB.repeat(rep, 2) if rep > 1 else BB,
                        preferred_element_type=jnp.float32)
        y_diag = jnp.einsum("bhts,bshp->bthp", CB * Lmat, xx,
                            preferred_element_type=jnp.float32)
        # inter-chunk: read carried state with decay exp(cumsum a)
        acs = jnp.cumsum(a_t, axis=-1)           # (b,H,c)
        y_off = jnp.einsum("bthn,bhpn,bht->bthp",
                           CC.repeat(rep, 2) if rep > 1 else CC, Sst,
                           jnp.exp(acs), preferred_element_type=jnp.float32)
        # chunk-out state: S' = exp(sum a) S + sum_t exp(suffix decay) B_t x_t
        a_tot = acs[..., -1]                     # (b,H)
        w = jnp.exp(a_tot[..., None] - acs)      # (b,H,c) suffix decay
        S_new = (Sst * jnp.exp(a_tot)[..., None, None]
                 + jnp.einsum("bht,bthn,bthp->bhpn", w,
                              BB.repeat(rep, 2) if rep > 1 else BB, xx,
                              preferred_element_type=jnp.float32))
        return S_new, y_diag + y_off

    Sf, ys = jax.lax.scan(chunk_step, S0, (xc, dac, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, S, H, P)
    y = y + x * D[None, None, :, None]
    return y.astype(x.dtype), Sf


def ssd_decode(x, dt, A, B, C, D, state):
    """One-token SSD step. x: (b,H,P); dt: (b,H); B,C: (b,G,N).

    state: (b,H,P,N). Returns (y (b,H,P), new state)."""
    G = B.shape[1]
    H = x.shape[1]
    rep = H // G
    da = jnp.exp(dt * A)                         # (b,H)
    Bx = jnp.einsum("bhp,bhn->bhpn", x * dt[..., None],
                    B.repeat(rep, 1) if rep > 1 else B)
    state = state * da[..., None, None] + Bx
    y = jnp.einsum("bhpn,bhn->bhp", state,
                   C.repeat(rep, 1) if rep > 1 else C,
                   preferred_element_type=jnp.float32)
    return (y + x * D[None, :, None]).astype(x.dtype), state


# ---------------------------------------------------------------------------
# Blocks / params
# ---------------------------------------------------------------------------


def _proj_sdrop(x, w, drop_state):
    if drop_state is None or drop_state.inactive:
        return jnp.einsum("bsd,dn->bsn", x, w,
                          preferred_element_type=jnp.float32).astype(x.dtype)
    if drop_state.structured:
        return sm.sdrop_matmul(x, w, drop_state.keep_blocks,
                               rate=drop_state.spec.rate,
                               block_size=drop_state.spec.block_size,
                               scale=drop_state.scale)
    return jnp.einsum("bsd,dn->bsn", drop_state.apply(x), w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _rms(g, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * g).astype(x.dtype)


def init_mamba_blocks(key, cfg: Mamba2Config, L: int):
    D, I, H, N = cfg.d_model, cfg.inner, cfg.n_heads, cfg.ssm_state
    G = 1                                        # single B/C group
    pd = cfg.param_dtype
    conv_dim = I + 2 * G * N
    ks = iter(jax.random.split(key, 8))

    def w(shape, axes, scale=None):
        s = scale if scale is not None else shape[-2] ** -0.5
        return tag((jax.random.normal(next(ks), shape) * s).astype(pd), *axes)

    # in_proj emits [z (I), x (I), B (GN), C (GN), dt (H)]
    return {
        "ln": {"g": tag(jnp.ones((L, D), pd), "layer", "norm")},
        "w_in": w((L, D, 2 * I + 2 * G * N + H), ("layer", "embed", "mlp")),
        "conv_w": tag(jnp.zeros((L, cfg.conv_kernel, conv_dim), pd),
                      "layer", "conv", "mlp"),
        "conv_b": tag(jnp.zeros((L, conv_dim), pd), "layer", "mlp"),
        "A_log": tag(jnp.log(jnp.linspace(1.0, 16.0, H))[None].repeat(L, 0)
                     .astype(pd), "layer", "heads"),
        "D": tag(jnp.ones((L, H), pd), "layer", "heads"),
        "dt_bias": tag(jnp.full((L, H), -2.0, pd), "layer", "heads"),
        "gn": {"g": tag(jnp.ones((L, I), pd), "layer", "norm")},
        "w_out": w((L, I, D), ("layer", "mlp", "embed")),
    }


def init_params(key, cfg: Mamba2Config):
    k_e, k_m, k_a, k_h = jax.random.split(key, 4)
    p = {
        "embed": tag((jax.random.normal(k_e, (cfg.vocab, cfg.d_model)) * 0.02
                      ).astype(cfg.param_dtype), "vocab", "embed"),
        "mamba": init_mamba_blocks(k_m, cfg, cfg.num_layers),
        "ln_f": {"g": tag(jnp.ones((cfg.d_model,), cfg.param_dtype), "norm")},
        "lm_head": tag((jax.random.normal(k_h, (cfg.d_model, cfg.vocab))
                        * cfg.d_model ** -0.5).astype(cfg.param_dtype),
                       "embed", "vocab"),
    }
    if cfg.shared_attn:
        tcfg = _shared_tcfg(cfg)
        p["shared"] = T.init_block_params(k_a, tcfg, 1)
        p["shared_in"] = tag(
            (jax.random.normal(jax.random.fold_in(k_a, 1),
                               (2 * cfg.d_model, cfg.d_model))
             * (2 * cfg.d_model) ** -0.5).astype(cfg.param_dtype),
            "mlp", "embed")
    return p


def _shared_tcfg(cfg: Mamba2Config) -> T.TransformerConfig:
    return T.TransformerConfig(
        num_layers=1, d_model=cfg.d_model, n_heads=cfg.attn_heads,
        n_kv_heads=cfg.attn_kv_heads, d_ff=cfg.attn_ff or 4 * cfg.d_model,
        vocab=cfg.vocab, param_dtype=cfg.param_dtype,
        compute_dtype=cfg.compute_dtype, q_chunk=512, kv_chunk=512,
        max_seq=1 << 20)


def _causal_conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def mamba_block_apply(pl, x, cfg: Mamba2Config, drop_state=None, initial=None):
    """x: (B,S,D) -> (B,S,D); returns (y, (ssm_state, conv_tail))."""
    Bb, S, Dm = x.shape
    I, H, N, P = cfg.inner, cfg.n_heads, cfg.ssm_state, cfg.head_p
    G = 1
    h = _rms(pl["ln"]["g"], x)
    zxbcdt = _proj_sdrop(h, pl["w_in"], drop_state)      # NR structured drop
    z, xbc, dt_raw = jnp.split(zxbcdt, [I, 2 * I + 2 * G * N], axis=-1)
    xbc = _causal_conv(xbc, pl["conv_w"], pl["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs, Bmat, Cmat = jnp.split(xbc, [I, I + G * N], axis=-1)
    dt = jax.nn.softplus(dt_raw + pl["dt_bias"])          # (B,S,H)
    A = -jnp.exp(pl["A_log"].astype(jnp.float32))         # (H,)
    y, Sf = ssd_chunked(xs.reshape(Bb, S, H, P), dt, A,
                        Bmat.reshape(Bb, S, G, N), Cmat.reshape(Bb, S, G, N),
                        pl["D"].astype(jnp.float32), cfg.chunk, initial=initial)
    y = y.reshape(Bb, S, I)
    y = _rms(pl["gn"]["g"], y) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, pl["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    conv_tail = xbc  # not carried in training
    return x + out, Sf


def forward(params, tokens, cfg: Mamba2Config, *, rules=None, ctx=None):
    if ctx is None:
        ctx = cfg.plan.bind(None)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = shard_act(x, ("batch", "seq", "embed_act"), rules)
    x0 = x                                                # zamba residual feed
    L = cfg.num_layers

    def m_scan(x, lo, hi):
        grp = jax.tree.map(lambda a: a[lo:hi], params["mamba"])

        def body(x, inp):
            pl, li = inp
            # layer index = the depth-scan time axis; inactive sites yield
            # a no-op state inside ctx.state
            ds = ctx.state("nr", x.shape[:2], cfg.d_model, t=li)
            y, _ = mamba_block_apply(pl, x, cfg, drop_state=ds)
            return y, None
        f = jax.checkpoint(body) if cfg.remat != "none" else body
        x, _ = jax.lax.scan(f, x, (grp, lo + jnp.arange(hi - lo)))
        return x

    if not cfg.shared_attn:
        x = m_scan(x, 0, L)
    else:
        tcfg = _shared_tcfg(cfg)
        shared = jax.tree.map(lambda a: a[0], params["shared"])
        lo = 0
        seg = cfg.shared_every
        while lo < L:
            hi = min(lo + seg, L)
            x = m_scan(x, lo, hi)
            if hi - lo == seg and hi < L + 1:
                inp = jnp.concatenate([x, x0], axis=-1)
                xin = jnp.einsum("bsd,dn->bsn", inp, params["shared_in"],
                                 preferred_element_type=jnp.float32
                                 ).astype(x.dtype)
                positions = jnp.arange(x.shape[1])[None].repeat(x.shape[0], 0)
                y, _ = T.block_apply(shared, xin, tcfg, causal=True,
                                     positions=positions, rules=rules)
                x = x + (y - xin)                # residual delta of the block
            lo = hi
    return _rms(params["ln_f"]["g"], x)


def lm_logits(params, feats):
    return jnp.einsum("bsd,dv->bsv", feats, params["lm_head"],
                      preferred_element_type=jnp.float32)


def loss_fn(params, batch, cfg: Mamba2Config, *, rules=None, drop_key=None,
            step=0):
    ctx = cfg.plan.bind(drop_key, step)
    feats = forward(params, batch["tokens"], cfg, rules=rules, ctx=ctx)
    tcfg = T.TransformerConfig(vocab=cfg.vocab, d_model=cfg.d_model,
                               loss_chunks=cfg.loss_chunks)
    return T.lm_loss({"lm_head": params["lm_head"]}, feats, batch["labels"],
                     tcfg, rules=rules)


# ------------------------------- serving ----------------------------------


def init_state(cfg: Mamba2Config, batch: int, max_seq: int = 0,
               dtype=jnp.float32):
    """Recurrent state; + KV caches for the shared attention applications."""
    L, H, P, N = cfg.num_layers, cfg.n_heads, cfg.head_p, cfg.ssm_state
    G = 1
    conv_dim = cfg.inner + 2 * G * N
    st = {
        "ssm": jnp.zeros((L, batch, H, P, N), dtype),   # fp32 for stability
        "conv": jnp.zeros((L, batch, cfg.conv_kernel - 1, conv_dim),
                          cfg.compute_dtype),
    }
    if cfg.shared_attn and max_seq:
        n_app = cfg.num_layers // cfg.shared_every
        tcfg = _shared_tcfg(cfg)
        KV, hd = tcfg.n_kv_eff, tcfg.hd
        st["attn_k"] = jnp.zeros((n_app, batch, max_seq, KV, hd),
                                 cfg.compute_dtype)
        st["attn_v"] = jnp.zeros((n_app, batch, max_seq, KV, hd),
                                 cfg.compute_dtype)
    return st


def decode_step(params, cfg: Mamba2Config, state, tokens, pos, *, rules=None):
    """One-token decode. tokens: (B,1). Returns (logits (B,1,V), state)."""
    Bb = tokens.shape[0]
    I, H, N, P = cfg.inner, cfg.n_heads, cfg.ssm_state, cfg.head_p
    G = 1
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(
        cfg.compute_dtype)                                  # (B,D)
    x0 = x
    new_state = dict(state)

    def m_body(x, inp):
        pl, Sst, conv = inp
        h = _rms(pl["ln"]["g"], x)
        zxbcdt = h @ pl["w_in"]
        z, xbc, dt_raw = jnp.split(zxbcdt, [I, 2 * I + 2 * G * N], axis=-1)
        win = jnp.concatenate([conv, xbc[:, None, :]], axis=1)
        xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", win, pl["conv_w"])
                          + pl["conv_b"])
        xs, Bmat, Cmat = jnp.split(xbc, [I, I + G * N], axis=-1)
        dt = jax.nn.softplus(dt_raw + pl["dt_bias"])
        A = -jnp.exp(pl["A_log"].astype(jnp.float32))
        y, S2 = ssd_decode(xs.reshape(Bb, H, P), dt, A,
                           Bmat.reshape(Bb, G, N), Cmat.reshape(Bb, G, N),
                           pl["D"].astype(jnp.float32), Sst)
        y = _rms(pl["gn"]["g"], y.reshape(Bb, I)) * jax.nn.silu(z)
        return x + y @ pl["w_out"], (S2, win[:, 1:])

    def run_m(x, lo, hi):
        grp = jax.tree.map(lambda a: a[lo:hi], params["mamba"])

        def body(x, inp):
            return m_body(x, inp)
        x, (S2, conv2) = jax.lax.scan(
            body, x, (grp, state["ssm"][lo:hi], state["conv"][lo:hi]))
        new_state["ssm"] = new_state["ssm"].at[lo:hi].set(S2)
        new_state["conv"] = new_state["conv"].at[lo:hi].set(conv2)
        return x

    L = cfg.num_layers
    if not cfg.shared_attn:
        x = run_m(x, 0, L)
    else:
        tcfg = _shared_tcfg(cfg)
        shared = jax.tree.map(lambda a: a[0], params["shared"])
        lo, app = 0, 0
        while lo < L:
            hi = min(lo + cfg.shared_every, L)
            x = run_m(x, lo, hi)
            if hi - lo == cfg.shared_every:
                inp = jnp.concatenate([x, x0], axis=-1)
                xin = (inp @ params["shared_in"]).astype(x.dtype)[:, None, :]
                entry = {"k": state["attn_k"][app], "v": state["attn_v"][app]}
                y, new_entry = T._decode_block(shared, xin, tcfg, entry, pos,
                                               jnp.full((Bb, 1), pos), rules,
                                               None)
                new_state["attn_k"] = new_state["attn_k"].at[app].set(
                    new_entry["k"])
                new_state["attn_v"] = new_state["attn_v"].at[app].set(
                    new_entry["v"])
                x = x + (y[:, 0] - xin[:, 0])
                app += 1
            lo = hi
    feats = _rms(params["ln_f"]["g"], x)
    return (feats @ params["lm_head"])[:, None, :], new_state
