"""Pallas TPU flash attention (fwd + bwd), GQA-aware, causal/windowed.

The roofline analysis (EXPERIMENTS §Roofline) shows every train/prefill
cell memory-bound on attention intermediates: the XLA-level chunked
attention materializes (cq x ckv) fp32 score tiles through HBM in fwd AND
bwd. This kernel keeps the tiles VMEM-resident (classic flash): HBM traffic
drops from O(S^2) scores to O(S·d) operands — the single largest §Perf
lever, applied beyond the paper.

Layout: q (B, Hq, Sq, d), k/v (B, Hkv, Sk, d); grid (B*Hq, nq, nk) with the
kv loop innermost; fp32 running (m, l, acc) scratch across the kv loop.
Causal/window masking from absolute positions; GQA by indexing kv head
hq // group in the BlockSpec index_map (no materialized repeat).

Backward: standard two-pass flash bwd — dq in one pallas_call (kv inner),
dk/dv in another (q inner) — recomputing p from (q, k, delta=rowsum(do*o),
lse) so nothing quadratic is ever stored. Validated in interpret mode
against the pure-jnp oracle (tests/test_flash.py).
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask(qpos, kpos, causal, window):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), jnp.bool_)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= qpos[:, None] - kpos[None, :] < window
    return m


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, scale, causal, window, bq, bk, nk):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    i = pl.program_id(1)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_mask(qpos, kpos, causal, window), s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * alpha
                    + jax.lax.dot_general(
                        p.astype(v_ref.dtype), v_ref[...],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[...] = (m_scr[...] + jnp.log(l))[:, 0]


@functools.partial(
    jax.jit, static_argnames=("scale", "causal", "window", "bq", "bk",
                              "interpret"))
def _flash_fwd(q, k, v, *, scale, causal, window, bq, bk, interpret):
    B, Hq, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nq, nk = Sq // bq, Sk // bk
    grid = (B * Hq, nq, nk)

    qs = pl.BlockSpec((1, 1, bq, d), lambda h, i, j: (h // Hq, h % Hq, i, 0))
    ks = pl.BlockSpec((1, 1, bk, d),
                      lambda h, i, j: (h // Hq, (h % Hq) // G, j, 0))
    os = pl.BlockSpec((1, 1, bq, d), lambda h, i, j: (h // Hq, h % Hq, i, 0))
    ls = pl.BlockSpec((1, 1, bq), lambda h, i, j: (h // Hq, h % Hq, i))

    def kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr):
        _fwd_kernel(q_ref.at[0, 0], k_ref.at[0, 0], v_ref.at[0, 0],
                    o_ref.at[0, 0], lse_ref.at[0, 0], m_scr, l_scr, acc_scr,
                    scale=scale, causal=causal, window=window, bq=bq, bk=bk,
                    nk=nk)

    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[qs, ks, ks],
        out_specs=[os, ls],
        out_shape=[jax.ShapeDtypeStruct((B, Hq, Sq, d), q.dtype),
                   jax.ShapeDtypeStruct((B, Hq, Sq), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, scale, causal, window, bq, bk, nk):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    i = pl.program_id(1)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_mask(qpos, kpos, causal, window), s, NEG_INF)
    p = jnp.exp(s - lse_ref[...][:, None])
    do = do_ref[...].astype(jnp.float32)
    dp = jax.lax.dot_general(do, v_ref[...].astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[...][:, None]) * scale
    acc_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _flush():
        dq_ref[...] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, scale, causal, window, bq, bk, nq):
    i = pl.program_id(2)          # q loop innermost

    @pl.when(i == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    j = pl.program_id(1)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_mask(qpos, kpos, causal, window), s, NEG_INF)
    p = jnp.exp(s - lse_ref[...][:, None])            # (bq, bk)
    do = do_ref[...].astype(jnp.float32)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v_ref[...].astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[...][:, None]) * scale
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(i == nq - 1)
    def _flush():
        dk_ref[...] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[...] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "causal", "window", "bq", "bk",
                              "interpret"))
def _flash_bwd(q, k, v, o, lse, do, *, scale, causal, window, bq, bk,
               interpret):
    B, Hq, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nq, nk = Sq // bq, Sk // bk
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32),
                    axis=-1)                      # (B, Hq, Sq)

    qs = pl.BlockSpec((1, 1, bq, d), lambda h, i, j: (h // Hq, h % Hq, i, 0))
    ks = pl.BlockSpec((1, 1, bk, d),
                      lambda h, i, j: (h // Hq, (h % Hq) // G, j, 0))
    ls = pl.BlockSpec((1, 1, bq), lambda h, i, j: (h // Hq, h % Hq, i))

    def dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                  acc):
        _dq_kernel(q_ref.at[0, 0], k_ref.at[0, 0], v_ref.at[0, 0],
                   do_ref.at[0, 0], lse_ref.at[0, 0], delta_ref.at[0, 0],
                   dq_ref.at[0, 0], acc, scale=scale, causal=causal,
                   window=window, bq=bq, bk=bk, nk=nk)

    dq = pl.pallas_call(
        dq_kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[qs, ks, ks, qs, ls, ls],
        out_specs=qs,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv: one pass per Q-HEAD (GQA heads accumulate via sum over group).
    qs2 = pl.BlockSpec((1, 1, bq, d), lambda h, j, i: (h // Hq, h % Hq, i, 0))
    ks2 = pl.BlockSpec((1, 1, bk, d),
                       lambda h, j, i: (h // Hq, (h % Hq) // G, j, 0))
    kqs2 = pl.BlockSpec((1, 1, bk, d), lambda h, j, i: (h // Hq, h % Hq, j, 0))
    ls2 = pl.BlockSpec((1, 1, bq), lambda h, j, i: (h // Hq, h % Hq, i))

    def dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dk_ref, dv_ref, dks, dvs):
        _dkv_kernel(q_ref.at[0, 0], k_ref.at[0, 0], v_ref.at[0, 0],
                    do_ref.at[0, 0], lse_ref.at[0, 0], delta_ref.at[0, 0],
                    dk_ref.at[0, 0], dv_ref.at[0, 0], dks, dvs,
                    scale=scale, causal=causal, window=window, bq=bq, bk=bk,
                    nq=nq)

    dk_h, dv_h = pl.pallas_call(
        dkv_kernel,
        grid=(B * Hq, nk, nq),
        in_specs=[qs2, ks2, ks2, qs2, ls2, ls2],
        out_specs=[kqs2, kqs2],
        out_shape=[jax.ShapeDtypeStruct((B, Hq, Sk, d), jnp.float32),
                   jax.ShapeDtypeStruct((B, Hq, Sk, d), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dk = dk_h.reshape(B, Hkv, G, Sk, d).sum(2).astype(k.dtype)
    dv = dv_h.reshape(B, Hkv, G, Sk, d).sum(2).astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# public op with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=None, bq=512, bk=512,
                    interpret=None):
    """q: (B, Sq, Hq, d); k, v: (B, Sk, Hkv, d) -> (B, Sq, Hq, d).

    GQA handled by head-index mapping (no kv repeat). Sliding-window
    masking supported (FLOPs of masked tiles are still executed; the
    wall-clock win on TPU comes from HBM traffic, not mask sparsity —
    the windowed XLA path already handles the FLOP side)."""
    o, _ = _fa_fwd_res(q, k, v, causal, window, bq, bk, interpret)
    return o


def _resolve(q, bq, bk, Sq, Sk, interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bq = min(bq, Sq)
    while Sq % bq:
        bq -= 1
    bk = min(bk, Sk)
    while Sk % bk:
        bk -= 1
    return bq, bk, interpret


def _fa_fwd_res(q, k, v, causal, window, bq, bk, interpret):
    B, Sq, Hq, d = q.shape
    Sk = k.shape[1]
    bq, bk, interpret = _resolve(q, bq, bk, Sq, Sk, interpret)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o, lse = _flash_fwd(qt, kt, vt, scale=d ** -0.5, causal=causal,
                        window=window, bq=bq, bk=bk, interpret=interpret)
    return o.transpose(0, 2, 1, 3), (q, k, v, o, lse)


def _fa_fwd(q, k, v, causal, window, bq, bk, interpret):
    o, res = _fa_fwd_res(q, k, v, causal, window, bq, bk, interpret)
    return o, res


def _fa_bwd(causal, window, bq, bk, interpret, res, do):
    q, k, v, o_t, lse = res
    B, Sq, Hq, d = q.shape
    Sk = k.shape[1]
    bq, bk, interpret = _resolve(q, bq, bk, Sq, Sk, interpret)
    dq, dk, dv = _flash_bwd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), o_t, lse, do.transpose(0, 2, 1, 3),
        scale=d ** -0.5, causal=causal, window=window, bq=bq, bk=bk,
        interpret=interpret)
    return (dq.transpose(0, 2, 1, 3), dk.transpose(0, 2, 1, 3),
            dv.transpose(0, 2, 1, 3))


flash_attention.defvjp(_fa_fwd, _fa_bwd)
