"""Block-gather matmul Pallas TPU kernel — the paper's compaction, fused.

The structured dropout mask is a set of kept hidden-unit *blocks* (masks.py).
Rather than materializing compacted copies of the operands in HBM, this kernel
gathers kept blocks on the fly through the ``BlockSpec index_map`` using the
kept-block ids scalar-prefetched into SMEM: the gather costs nothing beyond
the (1-p)-sized matmul itself.

Three variants cover the three training phases (sparse_matmul.py):
  FP  : y  = a[:, kept] @ b[kept, :]   (gather="b_rows")   input  sparsity
  BP  : dx = dy @ b[kept, :].T         (gather="b_rows", transpose_b)
                                                           output sparsity
  FFN : y  = a @ b[:, kept]            (gather="b_cols")   output sparsity
(The WG matmul needs no gather — its inputs are already compact.)

``gather_matmul_stepped`` extends the FP/BP variants to a whole *schedule*
of masks (the scheduled recurrent engine's Phase A): ``keep_blocks`` is a
``(T, nk)`` ids table and ``a`` carries a leading time axis. T becomes an
extra leading grid axis and the table is scalar-prefetched whole, so each
step's gather is resolved in the BlockSpec ``index_map`` (``ids[t, k]``) at
zero cost beyond the (1-p)-sized matmuls themselves — no per-step weight
copies ever land in HBM.

Tiling: grid = (M/bm, OUT/b_out, CONTRACT/b_k), k innermost; fp32 VMEM
accumulator, write-out on the last k step. The dropout ``block_size`` doubles
as the gathered dimension's tile, so production masks use 128/256 (MXU lane
aligned); ``interpret=True`` validates any size on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(ids_ref, a_ref, b_ref, o_ref, acc_ref, *, n_k: int, transpose_b: bool):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if transpose_b:
        b = b.T
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=(
    "block_size", "gather", "a_is_compact", "transpose_b", "bm", "bn", "bk",
    "interpret"))
def gather_matmul(a: jax.Array, b: jax.Array, keep_blocks: jax.Array, *,
                  block_size: int,
                  gather: str = "b_rows",
                  a_is_compact: bool = False,
                  transpose_b: bool = False,
                  bm: Optional[int] = None,
                  bn: Optional[int] = None,
                  bk: Optional[int] = None,
                  interpret: Optional[bool] = None) -> jax.Array:
    """See module docstring. a: (M, Ka), b: (K, N), keep_blocks: (nk,) int32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nk = keep_blocks.shape[0]
    bs = block_size
    M = a.shape[0]
    bm = bm or min(128, M)
    a = _pad_to(a, 0, bm)
    Mp = a.shape[0]
    gm = Mp // bm

    if gather == "b_rows" and not transpose_b:
        # y (M, N) = a_c (M, nk*bs) @ b[kept, :] (nk*bs, N); contract over kept.
        N = b.shape[1]
        bn = bn or min(128, N)
        b = _pad_to(b, 1, bn)
        gn = b.shape[1] // bn
        grid = (gm, gn, nk)
        if a_is_compact:
            a_spec = pl.BlockSpec((bm, bs), lambda i, j, k, ids: (i, k))
        else:
            a_spec = pl.BlockSpec((bm, bs), lambda i, j, k, ids: (i, ids[k]))
        b_spec = pl.BlockSpec((bs, bn), lambda i, j, k, ids: (ids[k], j))
        o_spec = pl.BlockSpec((bm, bn), lambda i, j, k, ids: (i, j))
        out_shape = jax.ShapeDtypeStruct((Mp, b.shape[1]), a.dtype)
        acc = pltpu.VMEM((bm, bn), jnp.float32)
        n_k, out_slice = nk, (slice(0, M), slice(0, N))
    elif gather == "b_rows" and transpose_b:
        # y (M, nk*bs) = a (M, N) @ b[kept, :].T; contract over N.
        N = a.shape[1]
        bk = bk or min(128, N)
        a = _pad_to(a, 1, bk)
        b = _pad_to(b, 1, bk)
        gk = a.shape[1] // bk
        grid = (gm, nk, gk)
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, k, ids: (i, k))
        b_spec = pl.BlockSpec((bs, bk), lambda i, j, k, ids: (ids[j], k))
        o_spec = pl.BlockSpec((bm, bs), lambda i, j, k, ids: (i, j))
        out_shape = jax.ShapeDtypeStruct((Mp, nk * bs), a.dtype)
        acc = pltpu.VMEM((bm, bs), jnp.float32)
        n_k, out_slice = gk, (slice(0, M), slice(None))
    elif gather == "b_cols":
        # y (M, nk*bs) = a (M, K) @ b[:, kept]; contract over K.
        K = b.shape[0]
        bk = bk or min(128, K)
        a = _pad_to(a, 1, bk)
        b = _pad_to(b, 0, bk)
        gk = b.shape[0] // bk
        grid = (gm, nk, gk)
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, k, ids: (i, k))
        b_spec = pl.BlockSpec((bk, bs), lambda i, j, k, ids: (k, ids[j]))
        o_spec = pl.BlockSpec((bm, bs), lambda i, j, k, ids: (i, j))
        out_shape = jax.ShapeDtypeStruct((Mp, nk * bs), a.dtype)
        acc = pltpu.VMEM((bm, bs), jnp.float32)
        n_k, out_slice = gk, (slice(0, M), slice(None))
    else:
        raise ValueError(f"bad gather={gather!r} transpose_b={transpose_b}")

    kernel = functools.partial(_mm_kernel, n_k=n_k,
                               transpose_b=(gather == "b_rows" and transpose_b))
    y = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[a_spec, b_spec],
            out_specs=o_spec,
            scratch_shapes=[acc],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(keep_blocks, a, b)
    return y[out_slice]


# ---------------------------------------------------------------------------
# Scheduled (per-step ids table) variant
# ---------------------------------------------------------------------------


def _mm_kernel_stepped(ids_ref, a_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                       transpose_b: bool):
    """Grid (T, gm, g_out, g_contract); contraction innermost (axis 3)."""
    del ids_ref  # consumed by the index_maps
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]
    b = b_ref[...]
    if transpose_b:
        b = b.T
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_size", "a_is_compact", "transpose_b", "bm", "bn", "bk",
    "interpret"))
def gather_matmul_stepped(a: jax.Array, b: jax.Array, keep_blocks: jax.Array,
                          *,
                          block_size: int,
                          a_is_compact: bool = False,
                          transpose_b: bool = False,
                          bm: Optional[int] = None,
                          bn: Optional[int] = None,
                          bk: Optional[int] = None,
                          interpret: Optional[bool] = None) -> jax.Array:
    """Per-step "b_rows" gather matmuls for a whole mask schedule.

    keep_blocks: (T, nk) int32 — step ``t`` contracts over its own kept
    blocks. Two variants (mirroring gather_matmul):

      not transpose_b (FP): a (T, M, nk*bs | K) -> y (T, M, N) = a_c @ b[kept_t]
      transpose_b     (BP): a (T, M, N)         -> y (T, M, nk*bs) = a @ b[kept_t].T
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    T, nk = keep_blocks.shape
    bs = block_size
    assert a.shape[0] == T, (a.shape, T)
    M = a.shape[1]
    bm = bm or min(128, M)
    a = _pad_to(a, 1, bm)
    gm = a.shape[1] // bm

    if not transpose_b:
        # y (T, M, N) = a_c (T, M, nk*bs) @ b[kept_t, :]; contract over kept.
        N = b.shape[1]
        bn = bn or min(128, N)
        b = _pad_to(b, 1, bn)
        gn = b.shape[1] // bn
        grid = (T, gm, gn, nk)
        if a_is_compact:
            a_spec = pl.BlockSpec((1, bm, bs), lambda t, i, j, k, ids: (t, i, k))
        else:
            a_spec = pl.BlockSpec((1, bm, bs),
                                  lambda t, i, j, k, ids: (t, i, ids[t, k]))
        b_spec = pl.BlockSpec((bs, bn), lambda t, i, j, k, ids: (ids[t, k], j))
        o_spec = pl.BlockSpec((1, bm, bn), lambda t, i, j, k, ids: (t, i, j))
        out_shape = jax.ShapeDtypeStruct((T, a.shape[1], b.shape[1]), a.dtype)
        acc = pltpu.VMEM((bm, bn), jnp.float32)
        n_k = nk
        out_slice = (slice(None), slice(0, M), slice(0, N))
    else:
        # y (T, M, nk*bs) = a (T, M, N) @ b[kept_t, :].T; contract over N.
        N = a.shape[2]
        bk = bk or min(128, N)
        a = _pad_to(a, 2, bk)
        b = _pad_to(b, 1, bk)
        gk = a.shape[2] // bk
        grid = (T, gm, nk, gk)
        a_spec = pl.BlockSpec((1, bm, bk), lambda t, i, j, k, ids: (t, i, k))
        b_spec = pl.BlockSpec((bs, bk), lambda t, i, j, k, ids: (ids[t, j], k))
        o_spec = pl.BlockSpec((1, bm, bs), lambda t, i, j, k, ids: (t, i, j))
        out_shape = jax.ShapeDtypeStruct((T, a.shape[1], nk * bs), a.dtype)
        acc = pltpu.VMEM((bm, bs), jnp.float32)
        n_k = gk
        out_slice = (slice(None), slice(0, M), slice(None))

    kernel = functools.partial(_mm_kernel_stepped, n_k=n_k,
                               transpose_b=transpose_b)
    y = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[a_spec, b_spec],
            out_specs=o_spec,
            scratch_shapes=[acc],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(keep_blocks, a, b)
    return y[out_slice]
