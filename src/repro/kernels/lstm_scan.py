"""Fused persistent-scan LSTM Pallas kernel — the whole recurrence in one call.

The scheduled engine (core/lstm.py) already hoists mask sampling and the
non-recurrent gate matmuls out of the ``lax.scan``, but its Phase-B scan body
is still 2+ separately dispatched XLA ops per time step, and the recurrent
weight U is re-fetched from HBM every step. This kernel runs the *entire*
T-step Phase-B recurrence in a single ``pallas_call``:

  * U and the precomputed gate inputs' layout are set up so U is loaded into
    VMEM **once** and stays resident across all T steps (its BlockSpec
    index_map is constant; the time axis is the grid, and TPU grid steps on
    one core run sequentially, so the pipeline never evicts the block);
  * the time loop is the kernel grid — the carried (h, c) state lives in
    VMEM scratch, never round-tripping to HBM between steps;
  * the paper's RH structured dropout is applied by gathering each step's
    kept hidden-unit blocks straight out of the resident U via the
    scalar-prefetched ``(T, nk)`` MaskSchedule ids table (the same mechanism
    as ``gather_matmul_stepped``): the recurrent matmul runs at (1-p) FLOPs
    with zero-cost gathers — ``nk`` is static (exact-k masks), so the
    per-step gather unrolls into ``nk`` dynamic-slice + (B,bs)@(bs,4H)
    partial matmuls;
  * the LSTM pointwise update (kernels/lstm_pointwise.py math) is fused into
    the same pass — gates never land in HBM before the nonlinearity.

A ``custom_vjp`` pairs it with a reverse-time fused kernel: the backward
consumes the forward's residuals (pre-activation gates, the c sequence) and
runs the same per-step structure in reverse — dgates elementwise, the BP
matmul ``dgates @ U[kept].T`` and the WG accumulation ``h_c.T @ dgates`` both
gathered compact, dU accumulated in a VMEM f32 scratch across all T steps and
flushed once. Forward *and* backward recurrent matmuls run at (1-p) FLOPs.

Three RH modes (selected by which mask argument is given):
  structured  — ``keep_blocks`` (T|1, nk) ids table, compact gathers;
  random      — ``dense_mask`` (T|1, B, H), mask-multiply then dense matmul
                (baseline: regularization only, no reclaim);
  off         — dense recurrent matmul.
A (1, ...) leading axis is a FIXED time pattern: one mask reused every step.

``impl="xla"`` is the production CPU path: the same fused two-pass structure
(forward scan emitting residuals, hand-written reverse-time scan consuming
them) expressed as ``lax.scan``s, with the structured RH matmuls compact
(per-step h-column / U-row gathers by the schedule's unit ids — the
scheduled engine's in-scan math). Its edge over "scheduled" is the
hand-written backward: dU accumulates as a compact in-place scatter-add on
the scan carry where autodiff-of-scan materializes a dense (H, 4H)
zeros+scatter every step, FIXED schedules hoist the U gather out of the
scan entirely and keep dU compact until one final scatter, and the gate
bias rides in gx (masked-dense was tried first and measured ~0.7x of
scheduled at Zaremba-large geometry on CPU — the 1/(1-p) extra FLOPs beat
the saved gathers). The pallas path auto-falls back to interpret mode off
TPU, which validates the kernels but is not fast — benchmarks on CPU should
use ``impl="xla"``.

VMEM budget: U (H, 4H) must fit on-core alongside the (B, ·) working set —
~f32 H<=700 / bf16 H<=1000 on a 16 MB core. Beyond that the natural
extension is sharding H across cores (persistent-RNN style); not done here.
Tile alignment: on real TPU the dynamic slices want ``block_size`` a
multiple of the lane width (128) and B a multiple of 8; interpret mode
(CPU) validates any size.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _float0_like(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def _pointwise_fwd(gates, c_prev, forget_bias):
    """f32 gate nonlinearities + state update. gates: (B, 4H) order i,f,g,o."""
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + forget_bias) * c_prev + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def _pointwise_bwd(gates, c, c_prev, dh, dc_in, forget_bias):
    """Reverse of _pointwise_fwd from pre-activation gates.

    Returns (dgates (B, 4H), dc_prev (B, H)); dc_in is the carry from step
    t+1 (dL/dc_t through c_{t+1}), dh the total dL/dh_t.
    """
    gi, gf, gg, go = jnp.split(gates, 4, axis=-1)
    i = jax.nn.sigmoid(gi)
    f = jax.nn.sigmoid(gf + forget_bias)
    g = jnp.tanh(gg)
    o = jax.nn.sigmoid(go)
    tc = jnp.tanh(c)
    do = dh * tc
    dc = dc_in + dh * o * (1.0 - tc * tc)
    dgates = jnp.concatenate([
        (dc * g) * i * (1.0 - i),
        (dc * c_prev) * f * (1.0 - f),
        (dc * i) * (1.0 - g * g),
        do * o * (1.0 - o),
    ], axis=-1)
    return dgates, dc * f


# ---------------------------------------------------------------------------
# Pallas kernels. Grid = (T,): one grid step per time step, carry in scratch.
# ---------------------------------------------------------------------------


def _fwd_kernel(ids_ref, gx_ref, u_ref, h0_ref, c0_ref, m_ref,
                hs_ref, cs_ref, gates_ref, h_s, c_s, *,
                nk: int, block_size: int, scale: float, forget_bias: float,
                mode: str, fixed: bool):
    """One time step. mode: "structured" | "dense" | "off"."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)
        c_s[...] = c0_ref[...].astype(jnp.float32)

    h_prev = h_s[...]
    gates = gx_ref[0].astype(jnp.float32)
    if mode == "structured":
        bs = block_size
        acc = jnp.zeros_like(gates)
        for k in range(nk):                     # static unroll: exact-k masks
            bid = ids_ref[0 if fixed else t, k]
            hb = jax.lax.dynamic_slice(h_prev, (0, bid * bs),
                                       (h_prev.shape[0], bs))
            ub = u_ref[pl.ds(bid * bs, bs), :].astype(jnp.float32)
            acc += jnp.dot(hb, ub, preferred_element_type=jnp.float32)
        gates += acc * scale
    elif mode == "dense":
        hm = h_prev * m_ref[0].astype(jnp.float32) * scale
        gates += jnp.dot(hm, u_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    else:
        gates += jnp.dot(h_prev, u_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    h_new, c_new = _pointwise_fwd(gates, c_s[...], forget_bias)
    h_s[...] = h_new
    c_s[...] = c_new
    hs_ref[0] = h_new.astype(hs_ref.dtype)
    cs_ref[0] = c_new.astype(cs_ref.dtype)
    gates_ref[0] = gates.astype(gates_ref.dtype)


def _bwd_kernel(ids_ref, dy_ref, gates_ref, cs_ref, cp_ref, hp_ref, u_ref,
                m_ref, dcT_ref, dgx_ref, du_ref, dh0_ref, dc0_ref,
                dh_s, dc_s, du_s, *,
                n_steps: int, nk: int, block_size: int, scale: float,
                forget_bias: float, mode: str, fixed: bool):
    """Reverse-time step: grid step t processes time step r = T-1-t.

    All time-indexed refs arrive through r-indexed BlockSpecs; dU accumulates
    in f32 scratch across the whole grid and flushes on the last step.
    """
    t = pl.program_id(0)
    r = n_steps - 1 - t                      # the time step being processed

    @pl.when(t == 0)
    def _init():
        dh_s[...] = jnp.zeros_like(dh_s)
        dc_s[...] = dcT_ref[...].astype(jnp.float32)
        du_s[...] = jnp.zeros_like(du_s)

    dh = dy_ref[0].astype(jnp.float32) + dh_s[...]
    gates = gates_ref[0].astype(jnp.float32)
    c_t = cs_ref[0].astype(jnp.float32)
    c_prev = cp_ref[0].astype(jnp.float32)
    h_prev = hp_ref[0].astype(jnp.float32)
    dgates, dc_prev = _pointwise_bwd(gates, c_t, c_prev, dh, dc_s[...],
                                     forget_bias)
    dgx_ref[0] = dgates.astype(dgx_ref.dtype)

    B = dh.shape[0]
    if mode == "structured":
        bs = block_size
        dh_prev = jnp.zeros_like(dh)
        for k in range(nk):                     # static unroll
            bid = ids_ref[0 if fixed else r, k]
            ub = u_ref[pl.ds(bid * bs, bs), :].astype(jnp.float32)
            # BP: only the kept columns of dh_{t-1} get a contribution.
            dhb = jnp.dot(dgates, ub.T,
                          preferred_element_type=jnp.float32) * scale
            dh_prev = jax.lax.dynamic_update_slice(dh_prev, dhb, (0, bid * bs))
            # WG: compact (bs, 4H) product accumulated into the kept rows.
            hb = jax.lax.dynamic_slice(h_prev, (0, bid * bs), (B, bs))
            cur = du_s[pl.ds(bid * bs, bs), :]
            du_s[pl.ds(bid * bs, bs), :] = cur + jnp.dot(
                hb.T, dgates, preferred_element_type=jnp.float32) * scale
    elif mode == "dense":
        m = m_ref[0].astype(jnp.float32)
        dh_prev = jnp.dot(dgates, u_ref[...].astype(jnp.float32).T,
                          preferred_element_type=jnp.float32) * m * scale
        hm = h_prev * m * scale
        du_s[...] += jnp.dot(hm.T, dgates, preferred_element_type=jnp.float32)
    else:
        dh_prev = jnp.dot(dgates, u_ref[...].astype(jnp.float32).T,
                          preferred_element_type=jnp.float32)
        du_s[...] += jnp.dot(h_prev.T, dgates,
                             preferred_element_type=jnp.float32)
    dh_s[...] = dh_prev
    dc_s[...] = dc_prev

    @pl.when(t == n_steps - 1)
    def _flush():
        du_ref[...] = du_s[...].astype(du_ref.dtype)
        dh0_ref[...] = dh_prev.astype(dh0_ref.dtype)
        dc0_ref[...] = dc_prev.astype(dc0_ref.dtype)


def _rh_mode(kb, mask):
    if kb is not None:
        return "structured"
    if mask is not None:
        return "dense"
    return "off"


def _dummy_ids():
    return jnp.zeros((1, 1), jnp.int32)


def _pallas_fwd(gx, u, h0, c0, kb, mask, *, block_size, scale, forget_bias,
                interpret):
    T, B, H4 = gx.shape
    H = H4 // 4
    mode = _rh_mode(kb, mask)
    fixed = ((kb if mode == "structured" else mask) is not None
             and (kb if mode == "structured" else mask).shape[0] == 1)
    nk = kb.shape[1] if mode == "structured" else 0
    ids = kb if mode == "structured" else _dummy_ids()
    if mask is None:
        m_in = jnp.zeros((1, 1, 1), gx.dtype)       # unused placeholder
        m_spec = pl.BlockSpec((1, 1, 1), lambda t, ids: (0, 0, 0))
    else:
        m_in = mask
        m_spec = pl.BlockSpec((1, *mask.shape[1:]),
                              (lambda t, ids: (0, 0, 0)) if fixed
                              else (lambda t, ids: (t, 0, 0)))
    kernel = functools.partial(
        _fwd_kernel, nk=nk, block_size=block_size, scale=scale,
        forget_bias=forget_bias, mode=mode, fixed=fixed)
    hs, cs, gates = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(T,),
            in_specs=[
                pl.BlockSpec((1, B, H4), lambda t, ids: (t, 0, 0)),
                pl.BlockSpec((H, H4), lambda t, ids: (0, 0)),   # U resident
                pl.BlockSpec((B, H), lambda t, ids: (0, 0)),
                pl.BlockSpec((B, H), lambda t, ids: (0, 0)),
                m_spec,
            ],
            out_specs=[
                pl.BlockSpec((1, B, H), lambda t, ids: (t, 0, 0)),
                pl.BlockSpec((1, B, H), lambda t, ids: (t, 0, 0)),
                pl.BlockSpec((1, B, H4), lambda t, ids: (t, 0, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((B, H), jnp.float32),
                            pltpu.VMEM((B, H), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((T, B, H), gx.dtype),
                   jax.ShapeDtypeStruct((T, B, H), gx.dtype),
                   jax.ShapeDtypeStruct((T, B, H4), gx.dtype)],
        interpret=interpret,
    )(ids, gx, u, h0, c0, m_in)
    return hs, cs, gates


def _pallas_bwd(dy, dcT, gates, cs, c_prev_seq, h_prev_seq, u, kb, mask, *,
                block_size, scale, forget_bias, interpret):
    T, B, H4 = gates.shape
    H = H4 // 4
    mode = _rh_mode(kb, mask)
    fixed = ((kb if mode == "structured" else mask) is not None
             and (kb if mode == "structured" else mask).shape[0] == 1)
    nk = kb.shape[1] if mode == "structured" else 0
    ids = kb if mode == "structured" else _dummy_ids()
    rev = lambda t, ids: (T - 1 - t, 0, 0)          # reverse-time index map
    if mask is None:
        m_in = jnp.zeros((1, 1, 1), gates.dtype)
        m_spec = pl.BlockSpec((1, 1, 1), lambda t, ids: (0, 0, 0))
    else:
        m_in = mask
        m_spec = pl.BlockSpec((1, *mask.shape[1:]),
                              (lambda t, ids: (0, 0, 0)) if fixed else rev)
    kernel = functools.partial(
        _bwd_kernel, n_steps=T, nk=nk, block_size=block_size, scale=scale,
        forget_bias=forget_bias, mode=mode, fixed=fixed)
    dgx, du, dh0, dc0 = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(T,),
            in_specs=[
                pl.BlockSpec((1, B, H), rev),               # dy
                pl.BlockSpec((1, B, H4), rev),              # gates
                pl.BlockSpec((1, B, H), rev),               # c_t
                pl.BlockSpec((1, B, H), rev),               # c_{t-1}
                pl.BlockSpec((1, B, H), rev),               # h_{t-1}
                pl.BlockSpec((H, H4), lambda t, ids: (0, 0)),   # U resident
                m_spec,
                pl.BlockSpec((B, H), lambda t, ids: (0, 0)),    # dc_T
            ],
            out_specs=[
                pl.BlockSpec((1, B, H4), rev),
                pl.BlockSpec((H, H4), lambda t, ids: (0, 0)),
                pl.BlockSpec((B, H), lambda t, ids: (0, 0)),
                pl.BlockSpec((B, H), lambda t, ids: (0, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((B, H), jnp.float32),
                            pltpu.VMEM((B, H), jnp.float32),
                            pltpu.VMEM((H, H4), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((T, B, H4), gates.dtype),
                   jax.ShapeDtypeStruct((H, H4), u.dtype),
                   jax.ShapeDtypeStruct((B, H), gates.dtype),
                   jax.ShapeDtypeStruct((B, H), gates.dtype)],
        interpret=interpret,
    )(ids, dy, gates, cs, c_prev_seq, h_prev_seq, u, m_in, dcT)
    return dgx, du, dh0, dc0


# ---------------------------------------------------------------------------
# XLA impl: the same fused two-pass structure as lax.scans (CPU production
# path). Structured RH runs compact — per-step gathers of h columns / U rows
# by the schedule's unit ids, exactly the scheduled engine's in-scan math —
# while random RH is masked-dense (no structure to reclaim). The wins over
# "scheduled" come from the hand-written reverse-time scan: dU accumulates
# as a compact in-place scatter-add on the carry (autodiff-of-scan
# materializes a dense (H, 4H) zeros+scatter per step and adds it into the
# carry), FIXED schedules hoist the U gather and keep dU compact until one
# final scatter, and the gate bias is prefolded into gx.
# ---------------------------------------------------------------------------


def _unit_ids_table(kb, block_size):
    """(rows, nk) kept-block ids -> (rows, nk*bs) unit ids."""
    if block_size == 1:
        return kb
    offs = jnp.arange(block_size, dtype=kb.dtype)
    return (kb[..., None] * block_size + offs).reshape(kb.shape[0], -1)


def _xla_fwd(gx, u, h0, c0, kb, mask, *, block_size, scale, forget_bias):
    mode = _rh_mode(kb, mask)
    fixed = (mode != "off"
             and (kb if mode == "structured" else mask).shape[0] == 1)
    sc32 = jnp.asarray(scale, jnp.float32)
    sc = jnp.asarray(scale, gx.dtype)
    ids = _unit_ids_table(kb, block_size) if mode == "structured" else None
    u_c0 = jnp.take(u, ids[0], axis=0) if mode == "structured" and fixed \
        else None

    xs_extra = None
    if not fixed:
        xs_extra = ids if mode == "structured" else (
            mask if mode == "dense" else None)

    def step(carry, xs):
        h, c = carry
        gx_t, extra = xs
        if mode == "structured":
            ids_t = ids[0] if fixed else extra
            u_c = u_c0 if fixed else jnp.take(u, ids_t, axis=0)
            h_c = jnp.take(h, ids_t, axis=-1)
            r = jnp.dot(h_c, u_c, preferred_element_type=jnp.float32) * sc32
        elif mode == "dense":
            m_t = mask[0] if fixed else extra
            r = jnp.dot(h * m_t.astype(h.dtype) * sc, u,
                        preferred_element_type=jnp.float32)
        else:
            r = jnp.dot(h, u, preferred_element_type=jnp.float32)
        gates = gx_t.astype(jnp.float32) + r
        h2, c2 = _pointwise_fwd(gates, c.astype(jnp.float32), forget_bias)
        h2 = h2.astype(h.dtype)
        c2 = c2.astype(c.dtype)
        return (h2, c2), (h2, c2, gates.astype(gx.dtype))

    (hT, cT), (hs, cs, gates) = jax.lax.scan(step, (h0, c0), (gx, xs_extra))
    return hs, cs, gates


def _xla_bwd(dy, dcT, gates, cs, c_prev_seq, h_prev_seq, u, kb, mask, *,
             block_size, scale, forget_bias):
    T, B, H4 = gates.shape
    H = H4 // 4
    mode = _rh_mode(kb, mask)
    fixed = (mode != "off"
             and (kb if mode == "structured" else mask).shape[0] == 1)
    sc32 = jnp.asarray(scale, jnp.float32)
    ids = _unit_ids_table(kb, block_size) if mode == "structured" else None
    u_c0 = jnp.take(u, ids[0], axis=0) if mode == "structured" and fixed \
        else None
    # FIXED structured: dU stays compact (k, 4H) across the scan, one
    # scatter at the end; otherwise a full (H, 4H) f32 accumulator.
    du0 = jnp.zeros((ids.shape[1], H4) if mode == "structured" and fixed
                    else (H, H4), jnp.float32)

    xs_extra = None
    if not fixed:
        xs_extra = ids if mode == "structured" else (
            mask if mode == "dense" else None)

    def step(carry, xs):
        dh_next, dc_next, du = carry
        dy_t, g_t, c_t, cp_t, hp_t, extra = xs
        dh = dy_t.astype(jnp.float32) + dh_next
        dgates, dc_prev = _pointwise_bwd(
            g_t.astype(jnp.float32), c_t.astype(jnp.float32),
            cp_t.astype(jnp.float32), dh, dc_next, forget_bias)
        if mode == "structured":
            ids_t = ids[0] if fixed else extra
            u_c = u_c0 if fixed else jnp.take(u, ids_t, axis=0)
            # BP: only the kept columns of dh_{t-1} get a contribution.
            dh_c = jnp.dot(dgates, u_c.astype(jnp.float32).T,
                           preferred_element_type=jnp.float32) * sc32
            dh_prev = jnp.zeros((dh.shape[0], H), jnp.float32
                                ).at[:, ids_t].set(dh_c)
            # WG: compact (k, 4H) product scatter-added into the kept rows.
            h_c = jnp.take(hp_t, ids_t, axis=-1).astype(jnp.float32)
            contrib = jnp.dot(h_c.T, dgates,
                              preferred_element_type=jnp.float32) * sc32
            du = du + contrib if fixed else du.at[ids_t].add(contrib)
        elif mode == "dense":
            m_t = (mask[0] if fixed else extra).astype(jnp.float32)
            dh_prev = jnp.dot(dgates, u.astype(jnp.float32).T,
                              preferred_element_type=jnp.float32) * m_t * sc32
            hm = hp_t.astype(jnp.float32) * m_t * sc32
            du = du + jnp.dot(hm.T, dgates,
                              preferred_element_type=jnp.float32)
        else:
            dh_prev = jnp.dot(dgates, u.astype(jnp.float32).T,
                              preferred_element_type=jnp.float32)
            du = du + jnp.dot(hp_t.astype(jnp.float32).T, dgates,
                              preferred_element_type=jnp.float32)
        return (dh_prev, dc_prev, du), dgates.astype(dy.dtype)

    (dh0, dc0, du), dgx = jax.lax.scan(
        step, (jnp.zeros((dy.shape[1], H), jnp.float32),
               dcT.astype(jnp.float32), du0),
        (dy, gates, cs, c_prev_seq, h_prev_seq, xs_extra),
        reverse=True)
    if mode == "structured" and fixed:
        du = jnp.zeros((H, H4), jnp.float32).at[ids[0]].set(du)
    return (dgx, du.astype(u.dtype), dh0.astype(dy.dtype),
            dc0.astype(dy.dtype))


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _lstm_scan(block_size, scale, forget_bias, impl, interpret,
               gx, u, h0, c0, kb, mask):
    out, _ = _lstm_scan_fwd(block_size, scale, forget_bias, impl, interpret,
                            gx, u, h0, c0, kb, mask)
    return out


def _lstm_scan_fwd(block_size, scale, forget_bias, impl, interpret,
                   gx, u, h0, c0, kb, mask):
    if impl == "pallas":
        hs, cs, gates = _pallas_fwd(gx, u, h0, c0, kb, mask,
                                    block_size=block_size, scale=scale,
                                    forget_bias=forget_bias,
                                    interpret=interpret)
    else:
        hs, cs, gates = _xla_fwd(gx, u, h0, c0, kb, mask,
                                 block_size=block_size, scale=scale,
                                 forget_bias=forget_bias)
    out = (hs, hs[-1], cs[-1])
    return out, (gates, cs, hs, u, h0, c0, kb, mask)


def _lstm_scan_bwd(block_size, scale, forget_bias, impl, interpret, res, dout):
    gates, cs, hs, u, h0, c0, kb, mask = res
    dhs, dh_fin, dc_fin = dout
    # dL/dh_T arrives both through hs[-1] and the explicit final state.
    dy = dhs.at[-1].add(dh_fin)
    c_prev_seq = jnp.concatenate([c0[None], cs[:-1]], axis=0)
    h_prev_seq = jnp.concatenate([h0[None], hs[:-1]], axis=0)
    if impl == "pallas":
        dgx, du, dh0, dc0 = _pallas_bwd(
            dy, dc_fin, gates, cs, c_prev_seq, h_prev_seq, u, kb, mask,
            block_size=block_size, scale=scale, forget_bias=forget_bias,
            interpret=interpret)
    else:
        dgx, du, dh0, dc0 = _xla_bwd(
            dy, dc_fin, gates, cs, c_prev_seq, h_prev_seq, u, kb, mask,
            block_size=block_size, scale=scale, forget_bias=forget_bias)
    dkb = None if kb is None else _float0_like(kb)
    dmask = None if mask is None else jnp.zeros_like(mask)
    return dgx, du, dh0, dc0, dkb, dmask


_lstm_scan.defvjp(_lstm_scan_fwd, _lstm_scan_bwd)


@functools.partial(jax.jit, static_argnames=(
    "block_size", "scale", "forget_bias", "impl", "interpret"))
def lstm_scan(gx: jax.Array, u: jax.Array, h0: jax.Array, c0: jax.Array, *,
              keep_blocks: Optional[jax.Array] = None,
              dense_mask: Optional[jax.Array] = None,
              block_size: int = 1,
              scale: float = 1.0,
              forget_bias: float = 0.0,
              impl: str = "pallas",
              interpret: Optional[bool] = None):
    """Run the full Phase-B LSTM recurrence in one fused pass.

    gx: (T, B, 4H) precomputed non-recurrent gate inputs ``x_t @ W + b``
    (Phase A of the scheduled engine, bias folded in); u: (H, 4H); h0/c0:
    (B, H). RH dropout: ``keep_blocks`` (T|1, nk) structured ids table OR
    ``dense_mask`` (T|1, B, H) random mask, with inverted-dropout ``scale``;
    a leading 1 means FIXED (one mask for all steps). Returns
    ``(hs (T, B, H), (h_fin, c_fin))`` and is differentiable w.r.t.
    (gx, u, h0, c0) through the fused reverse-time backward.
    """
    if keep_blocks is not None and dense_mask is not None:
        raise ValueError("give at most one of keep_blocks / dense_mask")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    hs, h_fin, c_fin = _lstm_scan(int(block_size), float(scale),
                                  float(forget_bias), impl, bool(interpret),
                                  gx, u, h0, c0, keep_blocks, dense_mask)
    return hs, (h_fin, c_fin)
