"""Fused persistent-scan LSTM — the vanilla-cell instance of cell_scan.

The whole T-step Phase-B LSTM recurrence runs in one ``pallas_call``
(``kernels/cell_scan.py`` holds the shared machinery):

  * U is loaded into VMEM **once** and stays resident across all T steps
    (constant BlockSpec index_map; the time axis is the grid, and TPU grid
    steps on one core run sequentially, so the pipeline never evicts it);
  * the carried (h, c) state lives in VMEM scratch, never round-tripping
    to HBM between steps;
  * the paper's RH structured dropout gathers each step's kept hidden-unit
    blocks straight out of the resident U via the scalar-prefetched
    ``(T, nk)`` MaskSchedule ids table — the recurrent matmul runs at
    (1-p) FLOPs with zero-cost gathers (``nk`` static, exact-k masks);
  * the LSTM pointwise update (this module: sigmoid/tanh gate math on
    pre-activation gates in order i,f,g,o) is fused into the same pass;
  * a ``custom_vjp`` reverse-time kernel makes the backward equally fused:
    dgates elementwise from the stored pre-activation gates + c sequence,
    BP/WG gathered compact, dU accumulated in f32 VMEM scratch and flushed
    once. Forward *and* backward recurrent matmuls run at (1-p) FLOPs.

Three RH modes (selected by which mask argument is given): ``keep_blocks``
(T|1, nk) structured ids table (compact gathers); ``dense_mask``
(T|1, B, H) random mask (mask-multiply then dense matmul — regularization
only, no reclaim); neither = dense recurrence. A leading 1 row is a FIXED
time pattern (one mask reused every step).

``impl="xla"`` is the production CPU path: the same fused two-pass
structure expressed as ``lax.scan``s with compact structured gathers. Its
edge over "scheduled" is the hand-written backward: dU accumulates as a
compact in-place scatter-add on the scan carry where autodiff-of-scan
materializes a dense (H, 4H) zeros+scatter every step, FIXED schedules
hoist the U gather out of the scan entirely and keep dU compact until one
final scatter, and the gate bias rides in gx (masked-dense was tried first
and measured ~0.7x of scheduled at Zaremba-large geometry on CPU — the
1/(1-p) extra FLOPs beat the saved gathers). The pallas path auto-falls
back to interpret mode off TPU — correct but not fast.

VMEM budget: U (H, 4H) must fit on-core alongside the (B, ·) working set —
~f32 H<=700 / bf16 H<=1000 on a 16 MB core. Beyond that the natural
extension is sharding H across cores (persistent-RNN style); not done
here. Tile alignment: on real TPU the dynamic slices want ``block_size`` a
multiple of the lane width (128) and B a multiple of 8; interpret mode
(CPU) validates any size.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.cell_scan import CellSpec, cell_scan


def _pointwise_fwd(gates, states, *, forget_bias):
    """f32 gate nonlinearities + state update. gates order i,f,g,o."""
    (c_prev,) = states
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + forget_bias) * c_prev + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, (c,)


def _pointwise_bwd(gates, states_prev, states_new, dh, dstates, *,
                   forget_bias):
    """Reverse of _pointwise_fwd from pre-activation gates.

    dstates carries (dL/dc_t through c_{t+1},); dh is the total dL/dh_t.
    """
    (c_prev,), (c,) = states_prev, states_new
    (dc_in,) = dstates
    gi, gf, gg, go = jnp.split(gates, 4, axis=-1)
    i = jax.nn.sigmoid(gi)
    f = jax.nn.sigmoid(gf + forget_bias)
    g = jnp.tanh(gg)
    o = jax.nn.sigmoid(go)
    tc = jnp.tanh(c)
    do = dh * tc
    dc = dc_in + dh * o * (1.0 - tc * tc)
    dgates = jnp.concatenate([
        (dc * g) * i * (1.0 - i),
        (dc * c_prev) * f * (1.0 - f),
        (dc * i) * (1.0 - g * g),
        do * o * (1.0 - o),
    ], axis=-1)
    return dgates, (dc * f,)


@functools.lru_cache(maxsize=None)
def lstm_cell_spec(forget_bias: float = 0.0) -> CellSpec:
    """The vanilla LSTM as a cell_scan CellSpec (cached: stable jit keys)."""
    return CellSpec(
        name="lstm", num_states=1,
        pointwise_fwd=functools.partial(_pointwise_fwd,
                                        forget_bias=forget_bias),
        pointwise_bwd=functools.partial(_pointwise_bwd,
                                        forget_bias=forget_bias))


def lstm_scan(gx: jax.Array, u: jax.Array, h0: jax.Array, c0: jax.Array, *,
              keep_blocks: Optional[jax.Array] = None,
              dense_mask: Optional[jax.Array] = None,
              block_size: int = 1,
              scale: float = 1.0,
              forget_bias: float = 0.0,
              impl: str = "pallas",
              interpret: Optional[bool] = None,
              lengths: Optional[jax.Array] = None):
    """Run the full Phase-B LSTM recurrence in one fused pass.

    gx: (T, B, 4H) precomputed non-recurrent gate inputs ``x_t @ W + b``
    (Phase A of the scheduled engine, bias folded in); u: (H, 4H); h0/c0:
    (B, H). RH dropout: ``keep_blocks`` (T|1, nk) structured ids table OR
    ``dense_mask`` (T|1, B, H) random mask, with inverted-dropout
    ``scale``; a leading 1 means FIXED (one mask for all steps). Returns
    ``(hs (T, B, H), (h_fin, c_fin))`` and is differentiable w.r.t.
    (gx, u, h0, c0) through the fused reverse-time backward.

    ``lengths`` (B,) int32 makes the batch ragged: row b freezes its
    (h, c) carry after step ``lengths[b]`` and frozen steps contribute
    zero gradient — see ``cell_scan.cell_scan`` for the exact contract.

    This is the dense-recurrence (heads=1) instance of
    ``cell_scan.cell_scan``; the head axis is added/stripped here.
    """
    dm = None if dense_mask is None else dense_mask[:, :, None, :]
    hs, (h_fin, (c_fin,)) = cell_scan(
        gx[:, :, None, :], u[None], h0[:, None], (c0[:, None],),
        cell=lstm_cell_spec(float(forget_bias)),
        keep_blocks=keep_blocks, dense_mask=dm, block_size=block_size,
        scale=scale, impl=impl, interpret=interpret, lengths=lengths)
    return hs[:, :, 0], (h_fin[:, 0], c_fin[:, 0])
