"""Cell-parametric fused persistent-scan recurrence (the engine="fused" core).

PR 3 built the fused persistent-scan kernel for the vanilla LSTM cell: the
entire T-step Phase-B recurrence in ONE ``pallas_call`` (time axis = kernel
grid, carried state in VMEM scratch, recurrent weight resident via a
constant BlockSpec index_map, per-step RH keep-block gathers unrolled off
the scalar-prefetched ``(T, nk)`` MaskSchedule ids table) paired with a
``custom_vjp`` reverse-time kernel, so forward AND backward recurrent
matmuls run at (1-p) FLOPs. That machinery is cell-agnostic — only the
per-step pointwise update (gate nonlinearities + state transition) and the
set of carried states are LSTM-specific.

This module factors the split. A ``CellSpec`` supplies the cell:

  * ``num_states`` — carried cell states besides ``h`` (LSTM: 1, the cell
    state c; sLSTM: 3, the (c, n, m) cell/normalizer/stabilizer triple);
  * ``pointwise_fwd(gates, states) -> (h_new, states_new)`` — f32 gate
    nonlinearities + state update from pre-activation gates;
  * ``pointwise_bwd(gates, states_prev, states_new, dh, dstates) ->
    (dgates, dstates_prev)`` — its hand-derived reverse, from the stored
    residuals (the forward's pre-activation gates and state sequences).

Everything else — the time-as-grid pallas forward/backward kernels, the
f32 VMEM dU accumulation flushed once, the XLA two-pass ``lax.scan`` impl
with the FIXED-schedule compact-dU optimization, and the ``custom_vjp``
wiring — lives here once and is shared by every cell
(``kernels/lstm_scan.py`` and ``kernels/slstm_scan.py`` instantiate it).

Shapes are head-parametric to cover block-diagonal recurrences: the hidden
state is ``(B, H, dh)`` (H recurrence blocks a.k.a. heads, dh units each),
the recurrent weight ``u`` is ``(H, dh, G)`` with ``G`` the per-head gate
width (4*dh for both cells), and the precomputed gate inputs ``gx`` are
``(T, B, H, G)``. A dense full recurrence is the H=1 case (the LSTM);
xLSTM's sLSTM uses its per-head block-diagonal R directly. The RH mask is
over ``dh`` and shared across heads (the xlstm convention — compacted
matmul shapes stay static): ``keep_blocks`` is a ``(T|1, nk)`` ids table
of dh-blocks, ``dense_mask`` is ``(T|1, B, 1|H, dh)``. A leading 1 row is
a FIXED time pattern (one mask reused every step).

**Ragged batches** (PR 8): an optional per-row ``lengths (B,) int32``
freezes each row's carries once its sequence ends. Forward, step t of row
b with ``t >= lengths[b]`` writes ``h_{t-1}`` / ``states_{t-1}`` through
unchanged (so ``hs[t, b]`` repeats the last valid state and the returned
finals are the states at each row's last REAL step — the handoff the NMT
encoder->decoder chain and serving prefill rely on). Backward, frozen
steps route the (dh, dstates) cotangents straight through to t-1 and
contribute exactly zero dgates/dU (the pointwise VJP is linear in its
cotangents, so zeroing them at frozen steps kills the whole step's grad).
In the pallas path ``lengths`` rides as a second scalar-prefetch operand
next to the schedule-ids table; ``t < lengths`` is the per-step activity
predicate in both directions. Packed-batch loss/grads therefore equal the
per-sequence unpacked reference bit-for-bit (tests/test_ragged.py).

Dtype contract: all pointwise math and matmul accumulation run in f32;
outputs are cast back so every cotangent carries its primal's dtype
(``dgx`` -> gx.dtype, ``du`` -> u.dtype, ``dh0``/``dstates0`` -> their
states' dtypes). A bf16-gx call never silently widens its grads.

Oracles: this module is tested against the plain-``lax.scan`` references
``kernels/ref.py::lstm_scan_ref`` (via kernels/lstm_scan.py) and
``kernels/ref.py::slstm_scan_ref`` (via kernels/slstm_scan.py), with
grads checked against autodiff of those references.

The pallas path targets TPU and auto-falls back to interpret mode off TPU
(correct, not fast); ``impl="xla"`` is the CPU production path. VMEM
budget and tile-alignment notes from PR 3 carry over per head: u
(H, dh, G) must fit on-core beside the (B, H, ·) working set, and on real
TPU the gathered ``block_size`` wants lane alignment (128) — interpret
mode validates any size.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One recurrent cell's pointwise math (see module docstring).

    Instances must be module-level constants (or lru_cached factories) so
    jit/custom_vjp caching keys stay stable across calls.
    """
    name: str
    num_states: int
    pointwise_fwd: Callable     # (gates, states) -> (h_new, states_new)
    pointwise_bwd: Callable     # (gates, st_prev, st_new, dh, dst)
                                # -> (dgates, dst_prev)


def _float0_like(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def _rh_mode(kb, mask):
    if kb is not None:
        return "structured"
    if mask is not None:
        return "dense"
    return "off"


def _is_fixed(mode, kb, mask):
    return mode != "off" and (kb if mode == "structured" else mask).shape[0] == 1


def _dummy_ids():
    return jnp.zeros((1, 1), jnp.int32)


def _dummy_lens():
    return jnp.zeros((1,), jnp.int32)


def _unit_ids_table(kb, block_size):
    """(rows, nk) kept-block ids -> (rows, nk*bs) unit ids."""
    if block_size == 1:
        return kb
    offs = jnp.arange(block_size, dtype=kb.dtype)
    return (kb[..., None] * block_size + offs).reshape(kb.shape[0], -1)


# ---------------------------------------------------------------------------
# Pallas kernels. Grid = (T,): one grid step per time step, carry in scratch.
# Variadic refs (the cell's state count is a parameter) are unpacked by
# position: [scalar ids, scalar lens | inputs | outputs | scratch]. The
# schedule-ids table AND the per-row lengths column both ride the scalar-
# prefetch path (num_scalar_prefetch=2); when the batch is rectangular the
# lens operand is a (1,) dummy and ``ragged=False`` compiles the predicate
# away entirely.
# ---------------------------------------------------------------------------


def _recurrent_fwd(gates, h_prev, u_ref, ids_ref, m_ref, t, *,
                   heads, nk, block_size, scale, mode, fixed):
    """Add the per-head recurrent matmul h_{t-1} @ U into ``gates``."""
    bs = block_size
    out = []
    if mode == "structured":
        for hd in range(heads):
            hh = h_prev[:, hd]
            acc = jnp.zeros_like(gates[:, hd])
            for k in range(nk):                 # static unroll: exact-k masks
                bid = ids_ref[0 if fixed else t, k]
                hb = jax.lax.dynamic_slice(hh, (0, bid * bs),
                                           (hh.shape[0], bs))
                ub = u_ref[hd, pl.ds(bid * bs, bs), :].astype(jnp.float32)
                acc += jnp.dot(hb, ub, preferred_element_type=jnp.float32)
            out.append(gates[:, hd] + acc * scale)
    elif mode == "dense":
        hm = h_prev * m_ref[0].astype(jnp.float32) * scale
        for hd in range(heads):
            out.append(gates[:, hd] + jnp.dot(
                hm[:, hd], u_ref[hd].astype(jnp.float32),
                preferred_element_type=jnp.float32))
    else:
        for hd in range(heads):
            out.append(gates[:, hd] + jnp.dot(
                h_prev[:, hd], u_ref[hd].astype(jnp.float32),
                preferred_element_type=jnp.float32))
    return jnp.stack(out, axis=1)


def _fwd_kernel(*args, cell: CellSpec, heads: int, nk: int, block_size: int,
                scale: float, mode: str, fixed: bool, ragged: bool):
    ns = cell.num_states
    ids_ref, lens_ref = args[0], args[1]
    gx_ref, u_ref, h0_ref = args[2:5]
    st0_refs = args[5:5 + ns]
    m_ref = args[5 + ns]
    hs_ref = args[6 + ns]
    gates_ref = args[7 + ns]
    stseq_refs = args[8 + ns:8 + 2 * ns]
    h_s = args[8 + 2 * ns]
    st_s = args[9 + 2 * ns:9 + 3 * ns]

    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)
        for s, s0 in zip(st_s, st0_refs):
            s[...] = s0[...].astype(jnp.float32)

    h_prev = h_s[...]
    gates = _recurrent_fwd(gx_ref[0].astype(jnp.float32), h_prev, u_ref,
                           ids_ref, m_ref, t, heads=heads, nk=nk,
                           block_size=block_size, scale=scale, mode=mode,
                           fixed=fixed)
    st_prev = tuple(s[...] for s in st_s)
    h_new, st_new = cell.pointwise_fwd(gates, st_prev)
    if ragged:
        # rows past their length freeze: carry t-1's state through unchanged
        act = (t < lens_ref[...])[:, None, None]
        h_new = jnp.where(act, h_new, h_prev)
        st_new = tuple(jnp.where(act, v, p)
                       for v, p in zip(st_new, st_prev))
    h_s[...] = h_new
    for s, v in zip(st_s, st_new):
        s[...] = v
    hs_ref[0] = h_new.astype(hs_ref.dtype)
    gates_ref[0] = gates.astype(gates_ref.dtype)
    for r, v in zip(stseq_refs, st_new):
        r[0] = v.astype(r.dtype)


def _bwd_kernel(*args, cell: CellSpec, heads: int, n_steps: int, nk: int,
                block_size: int, scale: float, mode: str, fixed: bool,
                ragged: bool):
    """Reverse-time step: grid step t processes time step r = T-1-t.

    All time-indexed refs arrive through r-indexed BlockSpecs; dU accumulates
    in f32 scratch across the whole grid and flushes on the last step.
    """
    ns = cell.num_states
    ids_ref, lens_ref = args[0], args[1]
    dy_ref, gates_ref = args[2:4]
    stn_refs = args[4:4 + ns]                  # states at t   (rev-indexed)
    stp_refs = args[4 + ns:4 + 2 * ns]         # states at t-1 (rev-indexed)
    hp_ref = args[4 + 2 * ns]
    u_ref = args[5 + 2 * ns]
    m_ref = args[6 + 2 * ns]
    dstT_refs = args[7 + 2 * ns:7 + 3 * ns]
    dgx_ref = args[7 + 3 * ns]
    du_ref = args[8 + 3 * ns]
    dh0_ref = args[9 + 3 * ns]
    dst0_refs = args[10 + 3 * ns:10 + 4 * ns]
    dh_s = args[10 + 4 * ns]
    dst_s = args[11 + 4 * ns:11 + 5 * ns]
    du_s = args[11 + 5 * ns]

    t = pl.program_id(0)
    r = n_steps - 1 - t                      # the time step being processed

    @pl.when(t == 0)
    def _init():
        dh_s[...] = jnp.zeros_like(dh_s)
        for s, d in zip(dst_s, dstT_refs):
            s[...] = d[...].astype(jnp.float32)
        du_s[...] = jnp.zeros_like(du_s)

    dh = dy_ref[0].astype(jnp.float32) + dh_s[...]
    dst_in = tuple(s[...] for s in dst_s)
    if ragged:
        # frozen steps: zero the cotangents into the cell (-> zero dgates,
        # zero dU contribution) and pass them through to t-1 afterwards
        act = (r < lens_ref[...])[:, None, None]
        dh_c = jnp.where(act, dh, 0.0)
        dst_c = tuple(jnp.where(act, d, 0.0) for d in dst_in)
    else:
        dh_c, dst_c = dh, dst_in
    gates = gates_ref[0].astype(jnp.float32)
    st_new = tuple(s[0].astype(jnp.float32) for s in stn_refs)
    st_prev = tuple(s[0].astype(jnp.float32) for s in stp_refs)
    h_prev = hp_ref[0].astype(jnp.float32)
    dgates, dst_prev = cell.pointwise_bwd(gates, st_prev, st_new, dh_c,
                                          dst_c)
    dgx_ref[0] = dgates.astype(dgx_ref.dtype)

    B = dh.shape[0]
    bs = block_size
    dhp = []
    if mode == "structured":
        for hd in range(heads):
            dgh = dgates[:, hd]
            hh = h_prev[:, hd]
            dh_h = jnp.zeros_like(dh[:, hd])
            for k in range(nk):                 # static unroll
                bid = ids_ref[0 if fixed else r, k]
                ub = u_ref[hd, pl.ds(bid * bs, bs), :].astype(jnp.float32)
                # BP: only the kept columns of dh_{t-1} get a contribution.
                dhb = jnp.dot(dgh, ub.T,
                              preferred_element_type=jnp.float32) * scale
                dh_h = jax.lax.dynamic_update_slice(dh_h, dhb, (0, bid * bs))
                # WG: compact (bs, G) product accumulated into the kept rows.
                hb = jax.lax.dynamic_slice(hh, (0, bid * bs), (B, bs))
                cur = du_s[hd, pl.ds(bid * bs, bs), :]
                du_s[hd, pl.ds(bid * bs, bs), :] = cur + jnp.dot(
                    hb.T, dgh, preferred_element_type=jnp.float32) * scale
            dhp.append(dh_h)
    elif mode == "dense":
        m = m_ref[0].astype(jnp.float32)         # (B, 1|H, dh)
        for hd in range(heads):
            u_h = u_ref[hd].astype(jnp.float32)
            dgh = dgates[:, hd]
            m_h = m[:, 0] if m.shape[1] == 1 else m[:, hd]
            dhp.append(jnp.dot(dgh, u_h.T,
                               preferred_element_type=jnp.float32)
                       * m_h * scale)
            hm = h_prev[:, hd] * m_h * scale
            du_s[hd] = du_s[hd] + jnp.dot(hm.T, dgh,
                                          preferred_element_type=jnp.float32)
    else:
        for hd in range(heads):
            u_h = u_ref[hd].astype(jnp.float32)
            dgh = dgates[:, hd]
            dhp.append(jnp.dot(dgh, u_h.T,
                               preferred_element_type=jnp.float32))
            du_s[hd] = du_s[hd] + jnp.dot(h_prev[:, hd].T, dgh,
                                          preferred_element_type=jnp.float32)
    dh_prev = jnp.stack(dhp, axis=1)
    if ragged:
        dh_prev = dh_prev + jnp.where(act, 0.0, dh)
        dst_prev = tuple(p + jnp.where(act, 0.0, d)
                         for p, d in zip(dst_prev, dst_in))
    dh_s[...] = dh_prev
    for s, v in zip(dst_s, dst_prev):
        s[...] = v

    @pl.when(t == n_steps - 1)
    def _flush():
        du_ref[...] = du_s[...].astype(du_ref.dtype)
        dh0_ref[...] = dh_prev.astype(dh0_ref.dtype)
        for rf, v in zip(dst0_refs, dst_prev):
            rf[...] = v.astype(rf.dtype)


def _mask_inputs(mask, dtype, fixed, rev=None):
    """(m_in, m_spec) for the (1, B, 1|H, dh) per-step mask ref."""
    if mask is None:
        m_in = jnp.zeros((1, 1, 1, 1), dtype)        # unused placeholder
        return m_in, pl.BlockSpec((1, 1, 1, 1), lambda t, *_: (0, 0, 0, 0))
    per_t = rev if rev is not None else (lambda t, *_: (t, 0, 0, 0))
    spec = pl.BlockSpec((1, *mask.shape[1:]),
                        (lambda t, *_: (0, 0, 0, 0)) if fixed else per_t)
    return mask, spec


def _pallas_fwd(cell, gx, u, h0, states0, kb, mask, lengths, *, block_size,
                scale, interpret):
    T, B, H, G = gx.shape
    dh = u.shape[1]
    ns = cell.num_states
    mode = _rh_mode(kb, mask)
    fixed = _is_fixed(mode, kb, mask)
    ragged = lengths is not None
    nk = kb.shape[1] if mode == "structured" else 0
    ids = kb if mode == "structured" else _dummy_ids()
    lens = lengths.astype(jnp.int32) if ragged else _dummy_lens()
    m_in, m_spec = _mask_inputs(mask, gx.dtype, fixed)
    const3 = pl.BlockSpec((B, H, dh), lambda t, *_: (0, 0, 0))
    seq3 = pl.BlockSpec((1, B, H, dh), lambda t, *_: (t, 0, 0, 0))
    odt = h0.dtype
    kernel = functools.partial(
        _fwd_kernel, cell=cell, heads=H, nk=nk, block_size=block_size,
        scale=scale, mode=mode, fixed=fixed, ragged=ragged)
    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(T,),
            in_specs=[
                pl.BlockSpec((1, B, H, G), lambda t, *_: (t, 0, 0, 0)),
                pl.BlockSpec((H, dh, G), lambda t, *_: (0, 0, 0)),  # U resident
                const3,
                *([const3] * ns),
                m_spec,
            ],
            out_specs=[
                seq3,
                pl.BlockSpec((1, B, H, G), lambda t, *_: (t, 0, 0, 0)),
                *([seq3] * ns),
            ],
            scratch_shapes=[pltpu.VMEM((B, H, dh), jnp.float32)] * (1 + ns),
        ),
        out_shape=[jax.ShapeDtypeStruct((T, B, H, dh), odt),
                   jax.ShapeDtypeStruct((T, B, H, G), gx.dtype),
                   *[jax.ShapeDtypeStruct((T, B, H, dh), s.dtype)
                     for s in states0]],
        interpret=interpret,
    )(ids, lens, gx, u, h0, *states0, m_in)
    hs, gates = outs[0], outs[1]
    return hs, gates, tuple(outs[2:])


def _pallas_bwd(cell, dy, dstT, gates, st_seqs, st_prev_seqs, h_prev_seq, u,
                kb, mask, lengths, *, block_size, scale, interpret):
    T, B, H, G = gates.shape
    dh = u.shape[1]
    ns = cell.num_states
    mode = _rh_mode(kb, mask)
    fixed = _is_fixed(mode, kb, mask)
    ragged = lengths is not None
    nk = kb.shape[1] if mode == "structured" else 0
    ids = kb if mode == "structured" else _dummy_ids()
    lens = lengths.astype(jnp.int32) if ragged else _dummy_lens()
    rev = lambda t, *_: (T - 1 - t, 0, 0, 0)         # reverse-time index map
    m_in, m_spec = _mask_inputs(mask, gates.dtype, fixed, rev=rev)
    const3 = pl.BlockSpec((B, H, dh), lambda t, *_: (0, 0, 0))
    rev3 = pl.BlockSpec((1, B, H, dh), rev)
    odt = dy.dtype
    kernel = functools.partial(
        _bwd_kernel, cell=cell, heads=H, n_steps=T, nk=nk,
        block_size=block_size, scale=scale, mode=mode, fixed=fixed,
        ragged=ragged)
    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(T,),
            in_specs=[
                rev3,                                       # dy
                pl.BlockSpec((1, B, H, G), rev),            # gates
                *([rev3] * ns),                             # states at t
                *([rev3] * ns),                             # states at t-1
                rev3,                                       # h_{t-1}
                pl.BlockSpec((H, dh, G), lambda t, *_: (0, 0, 0)),  # U
                m_spec,
                *([const3] * ns),                           # d(state_T)
            ],
            out_specs=[
                pl.BlockSpec((1, B, H, G), rev),            # dgx
                pl.BlockSpec((H, dh, G), lambda t, *_: (0, 0, 0)),  # dU
                const3,                                     # dh0
                *([const3] * ns),                           # d(state_0)
            ],
            scratch_shapes=[pltpu.VMEM((B, H, dh), jnp.float32)] * (1 + ns)
            + [pltpu.VMEM((H, dh, G), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((T, B, H, G), odt),
                   jax.ShapeDtypeStruct((H, dh, G), u.dtype),
                   jax.ShapeDtypeStruct((B, H, dh), odt),
                   *[jax.ShapeDtypeStruct((B, H, dh), odt)] * ns],
        interpret=interpret,
    )(ids, lens, dy, gates, *st_seqs, *st_prev_seqs, h_prev_seq, u, m_in,
      *dstT)
    dgx, du, dh0 = outs[0], outs[1], outs[2]
    return dgx, du, dh0, tuple(outs[3:])


# ---------------------------------------------------------------------------
# XLA impl: the same fused two-pass structure as lax.scans (CPU production
# path). Structured RH runs compact — per-step gathers of h columns / U rows
# by the schedule's unit ids — while random RH is masked-dense. The wins
# over "scheduled" come from the hand-written reverse-time scan: dU
# accumulates as a compact in-place scatter-add on the carry
# (autodiff-of-scan materializes a dense (H, dh, G) zeros+scatter per step
# and adds it into the carry), FIXED schedules hoist the U gather and keep
# dU compact until one final scatter, and the gate bias is prefolded into
# gx (see kernels/lstm_scan.py for the measurements behind these choices).
# ---------------------------------------------------------------------------


def _xla_fwd(cell, gx, u, h0, states0, kb, mask, lengths, *, block_size,
             scale):
    mode = _rh_mode(kb, mask)
    fixed = _is_fixed(mode, kb, mask)
    sc32 = jnp.asarray(scale, jnp.float32)
    ids = _unit_ids_table(kb, block_size) if mode == "structured" else None
    u_c0 = jnp.take(u, ids[0], axis=1) if mode == "structured" and fixed \
        else None

    xs_extra = None
    if not fixed:
        xs_extra = ids if mode == "structured" else (
            mask if mode == "dense" else None)
    ts = jnp.arange(gx.shape[0]) if lengths is not None else None

    def step(carry, xs):
        h, sts = carry
        gx_t, extra, t = xs
        if mode == "structured":
            ids_t = ids[0] if fixed else extra
            u_c = u_c0 if fixed else jnp.take(u, ids_t, axis=1)
            h_c = jnp.take(h, ids_t, axis=-1)
            r = jnp.einsum("bhk,hkg->bhg", h_c, u_c,
                           preferred_element_type=jnp.float32) * sc32
        elif mode == "dense":
            m_t = mask[0] if fixed else extra
            hm = h * m_t.astype(h.dtype) * jnp.asarray(scale, h.dtype)
            r = jnp.einsum("bhd,hdg->bhg", hm, u,
                           preferred_element_type=jnp.float32)
        else:
            r = jnp.einsum("bhd,hdg->bhg", h, u,
                           preferred_element_type=jnp.float32)
        gates = gx_t.astype(jnp.float32) + r
        h2, st2 = cell.pointwise_fwd(
            gates, tuple(s.astype(jnp.float32) for s in sts))
        h2 = h2.astype(h.dtype)
        st2 = tuple(v.astype(s.dtype) for v, s in zip(st2, sts))
        if lengths is not None:
            # rows past their length freeze: carry t-1's state through
            act = (t < lengths)[:, None, None]
            h2 = jnp.where(act, h2, h)
            st2 = tuple(jnp.where(act, v, s) for v, s in zip(st2, sts))
        return (h2, st2), (h2, st2, gates.astype(gx.dtype))

    (_, _), (hs, st_seqs, gates) = jax.lax.scan(step, (h0, states0),
                                                (gx, xs_extra, ts))
    return hs, gates, st_seqs


def _xla_bwd(cell, dy, dstT, gates, st_seqs, st_prev_seqs, h_prev_seq, u,
             kb, mask, lengths, *, block_size, scale):
    T, B, H, G = gates.shape
    dh_dim = u.shape[1]
    mode = _rh_mode(kb, mask)
    fixed = _is_fixed(mode, kb, mask)
    sc32 = jnp.asarray(scale, jnp.float32)
    ids = _unit_ids_table(kb, block_size) if mode == "structured" else None
    u_c0 = jnp.take(u, ids[0], axis=1) if mode == "structured" and fixed \
        else None
    # FIXED structured: dU stays compact (H, k, G) across the scan, one
    # scatter at the end; otherwise a full (H, dh, G) f32 accumulator.
    du0 = jnp.zeros((H, ids.shape[1], G) if mode == "structured" and fixed
                    else (H, dh_dim, G), jnp.float32)

    xs_extra = None
    if not fixed:
        xs_extra = ids if mode == "structured" else (
            mask if mode == "dense" else None)
    ts = jnp.arange(T) if lengths is not None else None

    def step(carry, xs):
        dh_next, dst_next, du = carry
        dy_t, g_t, stn_t, stp_t, hp_t, extra, t = xs
        dh = dy_t.astype(jnp.float32) + dh_next
        if lengths is not None:
            # frozen steps: zero the cotangents INTO the cell (pointwise_bwd
            # is linear in them, so dgates/du vanish for those rows) and
            # pass the originals straight through to t-1 below.
            act = (t < lengths)[:, None, None]
            dh_c = jnp.where(act, dh, 0.0)
            dst_c = tuple(jnp.where(act, d, 0.0) for d in dst_next)
        else:
            dh_c, dst_c = dh, dst_next
        dgates, dst_prev = cell.pointwise_bwd(
            g_t.astype(jnp.float32),
            tuple(s.astype(jnp.float32) for s in stp_t),
            tuple(s.astype(jnp.float32) for s in stn_t), dh_c, dst_c)
        if mode == "structured":
            ids_t = ids[0] if fixed else extra
            u_c = (u_c0 if fixed else jnp.take(u, ids_t, axis=1)
                   ).astype(jnp.float32)
            # BP: only the kept columns of dh_{t-1} get a contribution.
            dh_c = jnp.einsum("bhg,hkg->bhk", dgates, u_c,
                              preferred_element_type=jnp.float32) * sc32
            dh_prev = jnp.zeros((B, H, dh_dim), jnp.float32
                                ).at[:, :, ids_t].set(dh_c)
            # WG: compact (H, k, G) product scatter-added into the kept rows.
            h_c = jnp.take(hp_t, ids_t, axis=-1).astype(jnp.float32)
            contrib = jnp.einsum("bhk,bhg->hkg", h_c, dgates,
                                 preferred_element_type=jnp.float32) * sc32
            du = du + contrib if fixed else du.at[:, ids_t].add(contrib)
        elif mode == "dense":
            m_t = (mask[0] if fixed else extra).astype(jnp.float32)
            dh_prev = jnp.einsum("bhg,hdg->bhd", dgates,
                                 u.astype(jnp.float32),
                                 preferred_element_type=jnp.float32
                                 ) * m_t * sc32
            hm = hp_t.astype(jnp.float32) * m_t * sc32
            du = du + jnp.einsum("bhd,bhg->hdg", hm, dgates,
                                 preferred_element_type=jnp.float32)
        else:
            dh_prev = jnp.einsum("bhg,hdg->bhd", dgates,
                                 u.astype(jnp.float32),
                                 preferred_element_type=jnp.float32)
            du = du + jnp.einsum("bhd,bhg->hdg", hp_t.astype(jnp.float32),
                                 dgates, preferred_element_type=jnp.float32)
        if lengths is not None:
            dh_prev = dh_prev + jnp.where(act, 0.0, dh)
            dst_prev = tuple(p + jnp.where(act, 0.0, d)
                             for p, d in zip(dst_prev, dst_next))
        return (dh_prev, dst_prev, du), dgates.astype(dy.dtype)

    (dh0, dst0, du), dgx = jax.lax.scan(
        step,
        (jnp.zeros((B, H, dh_dim), jnp.float32),
         tuple(d.astype(jnp.float32) for d in dstT), du0),
        (dy, gates, st_seqs, st_prev_seqs, h_prev_seq, xs_extra, ts),
        reverse=True)
    if mode == "structured" and fixed:
        du = jnp.zeros((H, dh_dim, G), jnp.float32).at[:, ids[0]].set(du)
    return (dgx, du.astype(u.dtype), dh0.astype(dy.dtype),
            tuple(d.astype(dy.dtype) for d in dst0))


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _cell_scan(cell, block_size, scale, impl, interpret,
               gx, u, h0, states0, kb, mask, lengths):
    out, _ = _cell_scan_fwd(cell, block_size, scale, impl, interpret,
                            gx, u, h0, states0, kb, mask, lengths)
    return out


def _cell_scan_fwd(cell, block_size, scale, impl, interpret,
                   gx, u, h0, states0, kb, mask, lengths):
    if impl == "pallas":
        hs, gates, st_seqs = _pallas_fwd(cell, gx, u, h0, states0, kb, mask,
                                         lengths, block_size=block_size,
                                         scale=scale, interpret=interpret)
    else:
        hs, gates, st_seqs = _xla_fwd(cell, gx, u, h0, states0, kb, mask,
                                      lengths, block_size=block_size,
                                      scale=scale)
    out = (hs, hs[-1], tuple(s[-1] for s in st_seqs))
    return out, (gates, st_seqs, hs, u, h0, states0, kb, mask, lengths)


def _cell_scan_bwd(cell, block_size, scale, impl, interpret, res, dout):
    gates, st_seqs, hs, u, h0, states0, kb, mask, lengths = res
    dhs, dh_fin, dst_fin = dout
    # dL/dh_T arrives both through hs[-1] and the explicit final state.
    dy = dhs.at[-1].add(dh_fin)
    st_prev_seqs = tuple(
        jnp.concatenate([s0[None].astype(s.dtype), s[:-1]], axis=0)
        for s0, s in zip(states0, st_seqs))
    h_prev_seq = jnp.concatenate([h0[None].astype(hs.dtype), hs[:-1]], axis=0)
    if impl == "pallas":
        dgx, du, dh0, dst0 = _pallas_bwd(
            cell, dy, dst_fin, gates, st_seqs, st_prev_seqs, h_prev_seq, u,
            kb, mask, lengths, block_size=block_size, scale=scale,
            interpret=interpret)
    else:
        dgx, du, dh0, dst0 = _xla_bwd(
            cell, dy, dst_fin, gates, st_seqs, st_prev_seqs, h_prev_seq, u,
            kb, mask, lengths, block_size=block_size, scale=scale)
    dkb = None if kb is None else _float0_like(kb)
    dmask = None if mask is None else jnp.zeros_like(mask)
    dlens = None if lengths is None else _float0_like(lengths)
    # cotangents carry their primals' dtypes (gates stores gx.dtype): a
    # bf16-gx / f32-state call must not widen dgx to f32 — that doubles
    # grad memory and makes grad dtype engine-dependent.
    return (dgx.astype(gates.dtype), du.astype(u.dtype),
            dh0.astype(h0.dtype),
            tuple(d.astype(s.dtype) for d, s in zip(dst0, states0)),
            dkb, dmask, dlens)


_cell_scan.defvjp(_cell_scan_fwd, _cell_scan_bwd)


@functools.partial(jax.jit, static_argnames=(
    "cell", "block_size", "scale", "impl", "interpret"))
def cell_scan(gx: jax.Array, u: jax.Array, h0: jax.Array,
              states0: Tuple[jax.Array, ...], *,
              cell: CellSpec,
              keep_blocks: Optional[jax.Array] = None,
              dense_mask: Optional[jax.Array] = None,
              block_size: int = 1,
              scale: float = 1.0,
              impl: str = "pallas",
              interpret: Optional[bool] = None,
              lengths: Optional[jax.Array] = None):
    """Run one cell's full Phase-B recurrence in one fused pass.

    gx: (T, B, H, G) precomputed non-recurrent gate inputs (Phase A, bias
    folded in); u: (H, dh, G) per-head recurrent weights (H=1 = dense
    recurrence); h0: (B, H, dh); states0: tuple of ``cell.num_states``
    carried states, each (B, H, dh). RH dropout over the dh axis, shared
    across heads: ``keep_blocks`` (T|1, nk) structured ids table OR
    ``dense_mask`` (T|1, B, 1|H, dh) random mask, with inverted-dropout
    ``scale``; a leading 1 means FIXED (one mask for all steps). Returns
    ``(hs (T, B, H, dh), (h_fin, states_fin))`` and is differentiable
    w.r.t. (gx, u, h0, states0) through the fused reverse-time backward.

    ``lengths`` (B,) int32 makes the batch ragged: row ``b`` freezes after
    its ``lengths[b]``-th step — ``hs[t, b]`` repeats the last valid state
    for ``t >= lengths[b]``, final states are the states at the last real
    step, and frozen steps contribute exactly zero to every gradient.
    Equivalent to running each row unpacked at its own length (see
    tests/test_ragged.py); ``lengths=None`` keeps the rectangular path
    bit-identical to before.
    """
    if keep_blocks is not None and dense_mask is not None:
        raise ValueError("give at most one of keep_blocks / dense_mask")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    hs, h_fin, st_fin = _cell_scan(cell, int(block_size), float(scale),
                                   impl, bool(interpret),
                                   gx, u, h0, tuple(states0),
                                   keep_blocks, dense_mask, lengths)
    return hs, (h_fin, st_fin)
