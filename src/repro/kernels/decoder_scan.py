"""Fused two-pass seq2seq decoder recurrence (the NMT engine="fused" core).

Luong input feeding makes the decoder's step-t NR input ``[embed_t ;
h~_{t-1}]`` depend on step t-1's attention output, which is why the decoder
used to keep its whole NR matmul in-scan. The equivalence-preserving
restructure implemented here splits that joint matmul:

    [embed_t ; h~_{t-1}] @ W  ==  embed_t @ W_x  +  h~_{t-1} @ W_feed

The ``embed_t @ W_x`` half has NO sequential dependence — it hoists out of
the scan and runs time-batched through ``dense_sdrop_scheduled`` (Phase A,
(1-p) FLOPs, bias folded in) exactly like every other NR matmul. Only the
feed half stays recurrent, and it is carried through this module's fused
scan as one more recurrent matmul next to ``h_{t-1} @ U`` — gathered
compact off its own keep-block schedule, so the in-scan FLOPs are (1-p)
too. Attention itself cannot leave the forward scan (h~_{t-1} -> gates_t ->
h_t -> attention_t -> h~_t is a nonlinear chain), so each step's Luong
general attention + h~ readout runs inside the pass and the h~ sequence is
emitted for the time-batched pass 2 (output dropout + vocab projection) —
the attention residuals (alpha rows) double as the backward's softmax
state, which the hand-derived reverse pass would need even if attention
were recomputed batched.

Per decoder step t (nl stacked LSTM layers, states (h_l, c_l), feed h~):

    gates_0 = gx0_t + drop(h~_{t-1}) @ W_feed + drop(h_{0,t-1}) @ U_0
    gates_l = drop(h_{l-1,t}) @ W_l + b_l + drop(h_{l,t-1}) @ U_l   (l >= 1)
    h_l, c_l = lstm_pointwise(gates_l, c_l)
    scores   = h_top @ enc_proj^T + score_bias        (additive -1e30 mask)
    alpha    = softmax(scores);  ctx = alpha @ enc_out
    h~_t     = tanh([ctx ; h_top] @ w_comb)

Every in-scan dropout site has hidden-width H. Canonical site order (the
``sites`` argument, 2*nl entries):

    [ feed, rh_0 .. rh_{nl-1}, nr_1 .. nr_{nl-1} ]

each ``(keep_blocks (rows, nk) | None, dense_mask (rows, B, H) | None,
block_size, scale)`` with rows in {1, T} (1 = FIXED, one mask reused every
step — Case II/IV).

The backward is hand-derived and fused the same way ``cell_scan.py``'s is:
one reverse-time pass carrying (dh_l, dc_l, dfeed) with all weight grads
accumulated along the way — structured sites keep BP/WG compact (gather /
scatter-add on kept blocks only, FIXED keeps dU compact until one final
scatter), the attention backward re-derives dscores through the softmax
jacobian from the stored alpha rows, and dgx0 flows back into Phase A's
autodiff (dW_x, db, dembed). ``impl="xla"`` is the CPU production path
(hand-written ``lax.scan``s); ``impl="pallas"`` runs both directions as
single time-as-grid persistent kernels (state in VMEM scratch, weights +
encoder memory resident via constant index maps, ids tables scalar-
prefetched) and auto-falls back to interpret mode off TPU.

**Ragged batches** (PR 8): an optional per-row ``lengths (B,) int32``
rides as one more scalar-prefetch operand (appended after the 2*nl ids
tables, so ``num_scalar_prefetch = 2*nl + 1``; a (1,) dummy when
rectangular and the ``ragged`` flag compiles the predicate away). Forward:
step t of row b with ``t >= lengths[b]`` writes the t-1 carries (h_l, c_l,
feed) through unchanged, so the emitted h~ repeats the last valid readout
and the finals are the state at the last real step — which is what the
serving prefill handoff consumes. Backward: frozen steps zero the (dh, dc,
dh~) cotangents INTO the step math (pointwise + attention backward are
linear in them, so every weight/attention grad contribution vanishes) and
pass the original cotangents straight through to t-1. A token-packed
batch therefore produces bit-for-bit the loss and grads of running each
row unpacked at its own length (tests/test_ragged.py).

Dtype contract: all step math runs in f32 inside the scan regardless of
operand dtypes; residual sequences (gates, h, c, h~, alpha) are stored
f32 by the pallas path; the returned h~ sequence / feed final carry
``gx0.dtype`` and the h/c finals carry ``h0.dtype``/``c0.dtype``;
cotangents are cast back to each primal's dtype on the way out.

Oracle: every (impl, engine) combination is tested against
``kernels/ref.py::decoder_scan_ref`` — a plain ``jax.lax.scan``
transliteration of the step equations above differentiated by autodiff —
in tests/test_kernels.py and tests/test_engine.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cell_scan import (_dummy_ids, _dummy_lens, _float0_like,
                                     _is_fixed, _rh_mode, _unit_ids_table)
from repro.kernels.lstm_scan import _pointwise_bwd, _pointwise_fwd

F32 = jnp.float32


def _pw_fwd(gates, c_prev):
    h, (c,) = _pointwise_fwd(gates, (c_prev,), forget_bias=0.0)
    return h, c


def _pw_bwd(gates, c_prev, c_new, dh, dc):
    dgates, (dc_prev,) = _pointwise_bwd(gates, (c_prev,), (c_new,), dh,
                                        (dc,), forget_bias=0.0)
    return dgates, dc_prev


@dataclasses.dataclass(frozen=True)
class SiteDesc:
    """Static per-site dropout descriptor (hashable: jit/custom_vjp key)."""
    mode: str          # "structured" | "dense" | "off"
    fixed: bool        # one mask row reused for all T steps
    block_size: int
    scale: float
    nk: int            # kept blocks per row (structured only)


def _mk_site(kb, mask, block_size, scale):
    mode = _rh_mode(kb, mask)
    fixed = _is_fixed(mode, kb, mask)
    nk = kb.shape[1] if mode == "structured" else 0
    desc = SiteDesc(mode, fixed, int(block_size), float(scale), nk)
    return desc, (kb if mode == "structured" else mask)


def _site_weights(nl, ops):
    """Canonical site index -> the weight it drops into.

    0 -> w_feed, 1+l -> us[l] (l in [0, nl)), nl+l -> ws[l-1] (l in [1, nl)).
    """
    return [ops["w_feed"]] + list(ops["us"]) + list(ops["ws"])


# ---------------------------------------------------------------------------
# XLA impl: hand-written forward/reverse lax.scans (CPU production path).
# Same compact-gather / FIXED-hoist structure as cell_scan's _xla_fwd/_bwd,
# generalized to 2*nl sites + the in-scan attention (and its backward).
# ---------------------------------------------------------------------------


def _site_tables(descs, masks):
    """Per-site (unit-ids table, hoisted FIXED compact weight slot, xs)."""
    uids = [None] * len(descs)
    xs = [None] * len(descs)
    for i, d in enumerate(descs):
        if d.mode == "structured":
            uids[i] = _unit_ids_table(masks[i], d.block_size)
            if not d.fixed:
                xs[i] = uids[i]
        elif d.mode == "dense" and not d.fixed:
            xs[i] = masks[i]
    return uids, tuple(xs)


def _xla_fwd(nl, descs, ops, masks, lengths):
    gx0 = ops["gx0"]
    ws = _site_weights(nl, ops)
    uids, xs_extra = _site_tables(descs, masks)
    wc0 = [jnp.take(ws[i], uids[i][0], axis=0)
           if d.mode == "structured" and d.fixed else None
           for i, d in enumerate(descs)]
    ep = ops["enc_proj"].astype(F32)
    eo = ops["enc_out"].astype(F32)
    sb = ops["score_bias"].astype(F32)
    wcomb = ops["w_comb"].astype(F32)
    bs_l = [b.astype(F32) for b in ops["bs"]]

    def mm(x, i, extra):
        d = descs[i]
        if d.mode == "off":
            return jnp.dot(x, ws[i], preferred_element_type=F32)
        if d.mode == "structured":
            ids_t = uids[i][0] if d.fixed else extra
            w_c = wc0[i] if d.fixed else jnp.take(ws[i], ids_t, axis=0)
            return jnp.dot(jnp.take(x, ids_t, axis=-1), w_c,
                           preferred_element_type=F32) * d.scale
        m_t = masks[i][0] if d.fixed else extra
        return jnp.dot(x * m_t.astype(F32) * d.scale, ws[i],
                       preferred_element_type=F32)

    ts = jnp.arange(gx0.shape[0]) if lengths is not None else None

    def step(carry, xs):
        hs, cs, feed = carry
        gx0_t, extras, t = xs
        g = gx0_t.astype(F32) + mm(feed, 0, extras[0]) + mm(hs[0], 1,
                                                            extras[1])
        h, c = _pw_fwd(g, cs[0])
        gates, new_h, new_c = [g], [h], [c]
        cur = h
        for l in range(1, nl):
            g = (mm(cur, nl + l, extras[nl + l]) + bs_l[l - 1]
                 + mm(hs[l], 1 + l, extras[1 + l]))
            h, c = _pw_fwd(g, cs[l])
            gates.append(g)
            new_h.append(h)
            new_c.append(c)
            cur = h
        scores = jnp.einsum("bh,bsh->bs", cur, ep,
                            preferred_element_type=F32) + sb
        alpha = jax.nn.softmax(scores, axis=-1)
        ctxv = jnp.einsum("bs,bsh->bh", alpha, eo,
                          preferred_element_type=F32)
        htil = jnp.tanh(jnp.dot(jnp.concatenate([ctxv, cur], -1), wcomb,
                                preferred_element_type=F32))
        if lengths is not None:
            # rows past their length freeze every carry (h, c, feed)
            act = (t < lengths)[:, None]
            new_h = [jnp.where(act, v, p) for v, p in zip(new_h, hs)]
            new_c = [jnp.where(act, v, p) for v, p in zip(new_c, cs)]
            htil = jnp.where(act, htil, feed)
        return ((tuple(new_h), tuple(new_c), htil),
                (htil, tuple(gates), tuple(new_h), tuple(new_c), alpha))

    init = (tuple(ops["h0"][l].astype(F32) for l in range(nl)),
            tuple(ops["c0"][l].astype(F32) for l in range(nl)),
            ops["feed0"].astype(F32))
    (hF, cF, feedF), ys = jax.lax.scan(step, init, (gx0, xs_extra, ts))
    htil_seq, gates_seqs, h_seqs, c_seqs, alpha_seq = ys
    return (htil_seq, gates_seqs, h_seqs, c_seqs, alpha_seq,
            (jnp.stack(hF), jnp.stack(cF), feedF))


def _xla_bwd(nl, descs, ops, masks, lengths, res, dout):
    gates_seqs, h_seqs, c_seqs, htil_seq, alpha_seq = res
    d_htil, d_hfin, d_cfin, d_ffin = dout
    T, B, G = ops["gx0"].shape
    H = ops["w_feed"].shape[0]
    ws = _site_weights(nl, ops)
    uids, xs_extra = _site_tables(descs, masks)
    wc0 = [jnp.take(ws[i], uids[i][0], axis=0)
           if d.mode == "structured" and d.fixed else None
           for i, d in enumerate(descs)]
    ep = ops["enc_proj"].astype(F32)
    eo = ops["enc_out"].astype(F32)
    wcomb = ops["w_comb"].astype(F32)

    h_prev_seqs = tuple(
        jnp.concatenate([ops["h0"][l][None].astype(F32), h_seqs[l][:-1]])
        for l in range(nl))
    c_prev_seqs = tuple(
        jnp.concatenate([ops["c0"][l][None].astype(F32), c_seqs[l][:-1]])
        for l in range(nl))
    feed_prev_seq = jnp.concatenate(
        [ops["feed0"][None].astype(F32), htil_seq[:-1]])

    def bp(dg, i, extra):
        """Input grad through site i: masked (compact where structured)."""
        d = descs[i]
        if d.mode == "off":
            return jnp.dot(dg, ws[i].T, preferred_element_type=F32)
        if d.mode == "structured":
            ids_t = uids[i][0] if d.fixed else extra
            w_c = wc0[i] if d.fixed else jnp.take(ws[i], ids_t, axis=0)
            dx_c = jnp.dot(dg, w_c.T, preferred_element_type=F32) * d.scale
            return jnp.zeros((B, H), F32).at[:, ids_t].set(dx_c)
        m_t = masks[i][0] if d.fixed else extra
        return (jnp.dot(dg, ws[i].T, preferred_element_type=F32)
                * m_t.astype(F32) * d.scale)

    def wg_init(i):
        d = descs[i]
        if d.mode == "structured" and d.fixed:
            return jnp.zeros((uids[i].shape[1], G), F32)   # compact rows
        return jnp.zeros((H, G), F32)

    def wg_add(acc, x, dg, i, extra):
        d = descs[i]
        if d.mode == "off":
            return acc + jnp.einsum("bh,bg->hg", x, dg,
                                    preferred_element_type=F32)
        if d.mode == "structured":
            ids_t = uids[i][0] if d.fixed else extra
            contrib = jnp.einsum("bk,bg->kg", jnp.take(x, ids_t, axis=-1),
                                 dg, preferred_element_type=F32) * d.scale
            return acc + contrib if d.fixed else acc.at[ids_t].add(contrib)
        m_t = masks[i][0] if d.fixed else extra
        return acc + jnp.einsum("bh,bg->hg", x * m_t.astype(F32) * d.scale,
                                dg, preferred_element_type=F32)

    def wg_fin(acc, i):
        d = descs[i]
        if d.mode == "structured" and d.fixed:
            return jnp.zeros((H, G), F32).at[uids[i][0]].set(acc)
        return acc

    ts = jnp.arange(T) if lengths is not None else None

    def step(carry, xs):
        dh, dc, dfeed, accs, dbs, dwcomb, dep, deo = carry
        (dy_t, g_t, h_t, hp_t, c_t, cp_t, htil_t, fp_t, alpha_t,
         extras, t) = xs
        # h~ readout backward (tanh + w_comb + attention softmax jacobian)
        dhtil = dy_t.astype(F32) + dfeed
        if lengths is not None:
            # frozen rows: zero the cotangents into the step math (every
            # piece below is linear in them, so all weight/attention grads
            # vanish) and pass the originals through to t-1 at the end.
            act = (t < lengths)[:, None]
            dhtil_c = jnp.where(act, dhtil, 0.0)
        else:
            act, dhtil_c = None, dhtil
        dpre = dhtil_c * (1.0 - htil_t * htil_t)
        cur = h_t[nl - 1]
        ctxv = jnp.einsum("bs,bsh->bh", alpha_t, eo,
                          preferred_element_type=F32)
        dwcomb = dwcomb + jnp.einsum(
            "bi,bh->ih", jnp.concatenate([ctxv, cur], -1), dpre,
            preferred_element_type=F32)
        dcat = jnp.dot(dpre, wcomb.T, preferred_element_type=F32)
        dctx, dcur = dcat[:, :H], dcat[:, H:]
        dalpha = jnp.einsum("bh,bsh->bs", dctx, eo,
                            preferred_element_type=F32)
        deo = deo + jnp.einsum("bs,bh->bsh", alpha_t, dctx,
                               preferred_element_type=F32)
        dscores = alpha_t * (dalpha - jnp.sum(alpha_t * dalpha, -1,
                                              keepdims=True))
        dcur = dcur + jnp.einsum("bs,bsh->bh", dscores, ep,
                                 preferred_element_type=F32)
        dep = dep + jnp.einsum("bs,bh->bsh", dscores, cur,
                               preferred_element_type=F32)
        # LSTM stack backward, top layer down; NR input grads flow into the
        # SAME step's lower layer, RH/feed grads into the carry (t-1).
        dh_cur = list(dh)
        dh_cur[nl - 1] = dh_cur[nl - 1] + dcur
        new_dh, new_dc = [None] * nl, [None] * nl
        accs, dbs = list(accs), list(dbs)
        dgx0_t = None
        new_dfeed = None
        for l in reversed(range(nl)):
            if lengths is not None:
                dh_cell = jnp.where(act, dh_cur[l], 0.0)
                dc_cell = jnp.where(act, dc[l], 0.0)
            else:
                dh_cell, dc_cell = dh_cur[l], dc[l]
            dg, dc_prev = _pw_bwd(g_t[l], cp_t[l], c_t[l], dh_cell, dc_cell)
            new_dh[l] = bp(dg, 1 + l, extras[1 + l])
            accs[1 + l] = wg_add(accs[1 + l], hp_t[l], dg, 1 + l,
                                 extras[1 + l])
            new_dc[l] = dc_prev
            if lengths is not None:
                new_dh[l] = new_dh[l] + jnp.where(act, 0.0, dh_cur[l])
                new_dc[l] = new_dc[l] + jnp.where(act, 0.0, dc[l])
            if l > 0:
                dh_cur[l - 1] = dh_cur[l - 1] + bp(dg, nl + l,
                                                   extras[nl + l])
                accs[nl + l] = wg_add(accs[nl + l], h_t[l - 1], dg, nl + l,
                                      extras[nl + l])
                dbs[l - 1] = dbs[l - 1] + dg.sum(axis=0)
            else:
                dgx0_t = dg
                new_dfeed = bp(dg, 0, extras[0])
                if lengths is not None:
                    new_dfeed = new_dfeed + jnp.where(act, 0.0, dhtil)
                accs[0] = wg_add(accs[0], fp_t, dg, 0, extras[0])
        return ((tuple(new_dh), tuple(new_dc), new_dfeed, tuple(accs),
                 tuple(dbs), dwcomb, dep, deo), dgx0_t)

    init = (tuple(d_hfin[l].astype(F32) for l in range(nl)),
            tuple(d_cfin[l].astype(F32) for l in range(nl)),
            d_ffin.astype(F32),
            tuple(wg_init(i) for i in range(2 * nl)),
            tuple(jnp.zeros((G,), F32) for _ in range(nl - 1)),
            jnp.zeros((2 * H, H), F32),
            jnp.zeros(ep.shape, F32), jnp.zeros(eo.shape, F32))
    (dh0, dc0, dfeed0, accs, dbs, dwcomb, dep, deo), dgx = jax.lax.scan(
        step, init,
        (d_htil, gates_seqs, h_seqs, h_prev_seqs, c_seqs, c_prev_seqs,
         htil_seq, feed_prev_seq, alpha_seq, xs_extra, ts),
        reverse=True)
    accs = [wg_fin(a, i) for i, a in enumerate(accs)]
    return (dgx, accs, dbs, dwcomb, dep, deo,
            jnp.stack(dh0), jnp.stack(dc0), dfeed0)


# ---------------------------------------------------------------------------
# Pallas impl: one time-as-grid kernel per direction. Refs are variadic in
# nl and unpacked by position: [scalar ids x 2nl | inputs | outputs |
# scratch]. Weights + encoder memory stay resident (constant index maps);
# (h, c, feed) carries and every grad accumulator live in f32 VMEM scratch.
# ---------------------------------------------------------------------------


def _m3_inputs(mask, dtype, fixed, rev=None):
    """(m_in, m_spec) for a (1, B, H) per-step site-mask ref."""
    if mask is None:
        m_in = jnp.zeros((1, 1, 1), dtype)               # unused placeholder
        return m_in, pl.BlockSpec((1, 1, 1), lambda t, *_: (0, 0, 0))
    per_t = rev if rev is not None else (lambda t, *_: (t, 0, 0))
    spec = pl.BlockSpec((1, *mask.shape[1:]),
                        (lambda t, *_: (0, 0, 0)) if fixed else per_t)
    return mask, spec


def _pl_mm(x, w_ref, ids_ref, m_ref, t, d):
    """drop(x) @ w in f32 inside the kernel (compact when structured)."""
    if d.mode == "off":
        return jnp.dot(x, w_ref[...].astype(F32), preferred_element_type=F32)
    if d.mode == "structured":
        bs = d.block_size
        acc = jnp.zeros((x.shape[0], w_ref.shape[-1]), F32)
        for k in range(d.nk):                   # static unroll: exact-k masks
            bid = ids_ref[0 if d.fixed else t, k]
            xb = jax.lax.dynamic_slice(x, (0, bid * bs), (x.shape[0], bs))
            wb = w_ref[pl.ds(bid * bs, bs), :].astype(F32)
            acc += jnp.dot(xb, wb, preferred_element_type=F32)
        return acc * d.scale
    m = m_ref[0].astype(F32)
    return jnp.dot(x * m * d.scale, w_ref[...].astype(F32),
                   preferred_element_type=F32)


def _pl_fwd_kernel(*args, nl, descs, n_steps, ragged):
    ns = 2 * nl
    i = 0
    ids_refs = args[i:i + ns]; i += ns                              # noqa: E702
    lens_ref = args[i]; i += 1                                      # noqa: E702
    gx0 = args[i]; i += 1                                           # noqa: E702
    us = args[i:i + nl]; i += nl                                    # noqa: E702
    ws = args[i:i + nl - 1]; i += nl - 1                            # noqa: E702
    bs_l = args[i:i + nl - 1]; i += nl - 1                          # noqa: E702
    w_feed, w_comb, ep, eo, sb = args[i:i + 5]; i += 5              # noqa: E702
    h0, c0, f0 = args[i:i + 3]; i += 3                              # noqa: E702
    m_refs = args[i:i + ns]; i += ns                                # noqa: E702
    htil_r, alpha_r = args[i:i + 2]; i += 2                         # noqa: E702
    gates_rs = args[i:i + nl]; i += nl                              # noqa: E702
    h_rs = args[i:i + nl]; i += nl                                  # noqa: E702
    c_rs = args[i:i + nl]; i += nl                                  # noqa: E702
    hfin_r, cfin_r, ffin_r = args[i:i + 3]; i += 3                  # noqa: E702
    h_s, c_s, feed_s = args[i:i + 3]
    site_w = [w_feed] + list(us) + list(ws)

    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0[...].astype(F32)
        c_s[...] = c0[...].astype(F32)
        feed_s[...] = f0[...].astype(F32)

    def mm(x, i, extra_t):
        return _pl_mm(x, site_w[i], ids_refs[i], m_refs[i], extra_t,
                      descs[i])

    g = (gx0[0].astype(F32) + mm(feed_s[...], 0, t) + mm(h_s[0], 1, t))
    h, c = _pw_fwd(g, c_s[0])
    gates, new_h, new_c = [g], [h], [c]
    cur = h
    for l in range(1, nl):
        g = (mm(cur, nl + l, t) + bs_l[l - 1][0].astype(F32)
             + mm(h_s[l], 1 + l, t))
        h, c = _pw_fwd(g, c_s[l])
        gates.append(g)
        new_h.append(h)
        new_c.append(c)
        cur = h
    H = cur.shape[-1]
    scores = jnp.einsum("bh,bsh->bs", cur, ep[...].astype(F32),
                        preferred_element_type=F32) + sb[...].astype(F32)
    alpha = jax.nn.softmax(scores, axis=-1)
    ctxv = jnp.einsum("bs,bsh->bh", alpha, eo[...].astype(F32),
                      preferred_element_type=F32)
    wc = w_comb[...].astype(F32)
    htil = jnp.tanh(jnp.dot(ctxv, wc[:H], preferred_element_type=F32)
                    + jnp.dot(cur, wc[H:], preferred_element_type=F32))

    if ragged:
        # rows past their length freeze every carry (h, c, feed)
        act = (t < lens_ref[...])[:, None]
        new_h = [jnp.where(act, v, h_s[l]) for l, v in enumerate(new_h)]
        new_c = [jnp.where(act, v, c_s[l]) for l, v in enumerate(new_c)]
        htil = jnp.where(act, htil, feed_s[...])

    for l in range(nl):
        h_s[l] = new_h[l]
        c_s[l] = new_c[l]
        gates_rs[l][0] = gates[l].astype(gates_rs[l].dtype)
        h_rs[l][0] = new_h[l].astype(h_rs[l].dtype)
        c_rs[l][0] = new_c[l].astype(c_rs[l].dtype)
    feed_s[...] = htil
    htil_r[0] = htil.astype(htil_r.dtype)
    alpha_r[0] = alpha.astype(alpha_r.dtype)

    @pl.when(t == n_steps - 1)
    def _flush():
        hfin_r[...] = jnp.stack(new_h).astype(hfin_r.dtype)
        cfin_r[...] = jnp.stack(new_c).astype(cfin_r.dtype)
        ffin_r[...] = htil.astype(ffin_r.dtype)


def _pallas_fwd(nl, descs, ops, masks, lengths, *, interpret):
    gx0 = ops["gx0"]
    T, B, G = gx0.shape
    H = ops["w_feed"].shape[0]
    S = ops["enc_out"].shape[1]
    ns = 2 * nl
    ragged = lengths is not None
    ids = [masks[i] if d.mode == "structured" else _dummy_ids()
           for i, d in enumerate(descs)]
    lens = lengths.astype(jnp.int32) if ragged else _dummy_lens()
    m_ins, m_specs = [], []
    for i, d in enumerate(descs):
        m_in, m_spec = _m3_inputs(masks[i] if d.mode == "dense" else None,
                                  F32, d.fixed)
        m_ins.append(m_in)
        m_specs.append(m_spec)

    seq = lambda shp: pl.BlockSpec((1, *shp), lambda t, *_: (t,) + (0,) * len(shp))
    const = lambda shp: pl.BlockSpec(shp, lambda t, *_: (0,) * len(shp))

    kernel = functools.partial(_pl_fwd_kernel, nl=nl, descs=descs, n_steps=T,
                               ragged=ragged)
    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=ns + 1,
            grid=(T,),
            in_specs=[
                seq((B, G)),                                   # gx0
                *([const((H, G))] * nl),                       # U_l
                *([const((H, G))] * (nl - 1)),                 # W_l
                *([const((1, G))] * (nl - 1)),                 # b_l
                const((H, G)), const((2 * H, H)),              # w_feed/w_comb
                const((B, S, H)), const((B, S, H)),            # enc mem
                const((B, S)),                                 # score_bias
                const((nl, B, H)), const((nl, B, H)),          # h0/c0
                const((B, H)),                                 # feed0
                *m_specs,
            ],
            out_specs=[
                seq((B, H)), seq((B, S)),                      # htil/alpha
                *([seq((B, G))] * nl),                         # gates_l
                *([seq((B, H))] * nl), *([seq((B, H))] * nl),  # h_l/c_l
                const((nl, B, H)), const((nl, B, H)),          # finals
                const((B, H)),
            ],
            scratch_shapes=[pltpu.VMEM((nl, B, H), F32),
                            pltpu.VMEM((nl, B, H), F32),
                            pltpu.VMEM((B, H), F32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((T, B, H), F32),
                   jax.ShapeDtypeStruct((T, B, S), F32),
                   *[jax.ShapeDtypeStruct((T, B, G), F32)] * nl,
                   *[jax.ShapeDtypeStruct((T, B, H), F32)] * (2 * nl),
                   jax.ShapeDtypeStruct((nl, B, H), F32),
                   jax.ShapeDtypeStruct((nl, B, H), F32),
                   jax.ShapeDtypeStruct((B, H), F32)],
        interpret=interpret,
    )(*ids, lens, gx0, *ops["us"], *ops["ws"],
      *[b.reshape(1, G) for b in ops["bs"]],
      ops["w_feed"], ops["w_comb"], ops["enc_proj"], ops["enc_out"],
      ops["score_bias"], ops["h0"], ops["c0"], ops["feed0"], *m_ins)
    htil_seq, alpha_seq = outs[0], outs[1]
    gates_seqs = tuple(outs[2:2 + nl])
    h_seqs = tuple(outs[2 + nl:2 + 2 * nl])
    c_seqs = tuple(outs[2 + 2 * nl:2 + 3 * nl])
    finals = (outs[2 + 3 * nl], outs[3 + 3 * nl], outs[4 + 3 * nl])
    return htil_seq, gates_seqs, h_seqs, c_seqs, alpha_seq, finals


def _pl_bp(dg, w_ref, ids_ref, m_ref, r, d, H):
    """Input grad through a site, inside the kernel (masked/compact)."""
    if d.mode == "off":
        return jnp.dot(dg, w_ref[...].astype(F32).T,
                       preferred_element_type=F32)
    if d.mode == "structured":
        bs = d.block_size
        dx = jnp.zeros((dg.shape[0], H), F32)
        for k in range(d.nk):                   # static unroll
            bid = ids_ref[0 if d.fixed else r, k]
            wb = w_ref[pl.ds(bid * bs, bs), :].astype(F32)
            dxb = jnp.dot(dg, wb.T, preferred_element_type=F32) * d.scale
            dx = jax.lax.dynamic_update_slice(dx, dxb, (0, bid * bs))
        return dx
    m = m_ref[0].astype(F32)
    return (jnp.dot(dg, w_ref[...].astype(F32).T,
                    preferred_element_type=F32) * m * d.scale)


def _pl_wg(x, dg, acc_ref, ids_ref, m_ref, r, d):
    """Accumulate the site's weight grad into its f32 scratch in place."""
    if d.mode == "structured":
        bs = d.block_size
        B = x.shape[0]
        for k in range(d.nk):                   # static unroll
            bid = ids_ref[0 if d.fixed else r, k]
            xb = jax.lax.dynamic_slice(x, (0, bid * bs), (B, bs))
            cur = acc_ref[pl.ds(bid * bs, bs), :]
            acc_ref[pl.ds(bid * bs, bs), :] = cur + jnp.dot(
                xb.T, dg, preferred_element_type=F32) * d.scale
        return
    if d.mode == "dense":
        x = x * m_ref[0].astype(F32) * d.scale
    acc_ref[...] = acc_ref[...] + jnp.dot(x.T, dg,
                                          preferred_element_type=F32)


def _pl_bwd_kernel(*args, nl, descs, n_steps, ragged):
    ns = 2 * nl
    i = 0
    ids_refs = args[i:i + ns]; i += ns                              # noqa: E702
    lens_ref = args[i]; i += 1                                      # noqa: E702
    dy = args[i]; i += 1                                            # noqa: E702
    gates = args[i:i + nl]; i += nl                                 # noqa: E702
    hh = args[i:i + nl]; i += nl                                    # noqa: E702
    hp = args[i:i + nl]; i += nl                                    # noqa: E702
    cc = args[i:i + nl]; i += nl                                    # noqa: E702
    cp = args[i:i + nl]; i += nl                                    # noqa: E702
    htil, fprev, alpha = args[i:i + 3]; i += 3                      # noqa: E702
    us = args[i:i + nl]; i += nl                                    # noqa: E702
    ws = args[i:i + nl - 1]; i += nl - 1                            # noqa: E702
    w_feed, w_comb, ep, eo = args[i:i + 4]; i += 4                  # noqa: E702
    dhT, dcT, dfT = args[i:i + 3]; i += 3                           # noqa: E702
    m_refs = args[i:i + ns]; i += ns                                # noqa: E702
    dgx0_r = args[i]; i += 1                                        # noqa: E702
    du_rs = args[i:i + nl]; i += nl                                 # noqa: E702
    dw_rs = args[i:i + nl - 1]; i += nl - 1                         # noqa: E702
    db_rs = args[i:i + nl - 1]; i += nl - 1                         # noqa: E702
    dwf_r, dwc_r, dep_r, deo_r = args[i:i + 4]; i += 4              # noqa: E702
    dh0_r, dc0_r, df0_r = args[i:i + 3]; i += 3                     # noqa: E702
    dh_s, dc_s, dfeed_s = args[i:i + 3]; i += 3                     # noqa: E702
    acc_s = args[i:i + ns]; i += ns                                 # noqa: E702
    db_s = args[i:i + nl - 1]; i += nl - 1                          # noqa: E702
    dwc_s, dep_s, deo_s = args[i:i + 3]
    site_w = [w_feed] + list(us) + list(ws)

    t = pl.program_id(0)
    r = n_steps - 1 - t                      # the time step being processed

    @pl.when(t == 0)
    def _init():
        dh_s[...] = dhT[...].astype(F32)
        dc_s[...] = dcT[...].astype(F32)
        dfeed_s[...] = dfT[...].astype(F32)
        for a in acc_s:
            a[...] = jnp.zeros_like(a)
        for a in db_s:
            a[...] = jnp.zeros_like(a)
        dwc_s[...] = jnp.zeros_like(dwc_s)
        dep_s[...] = jnp.zeros_like(dep_s)
        deo_s[...] = jnp.zeros_like(deo_s)

    H = dy.shape[-1]
    htil_t = htil[0].astype(F32)
    alpha_t = alpha[0].astype(F32)
    eo32 = eo[...].astype(F32)
    ep32 = ep[...].astype(F32)
    cur = hh[nl - 1][0].astype(F32)

    dhtil = dy[0].astype(F32) + dfeed_s[...]
    if ragged:
        # frozen rows: zero the cotangents into the step math (linear in
        # them), pass the originals through to t-1 at the end.
        act = (r < lens_ref[...])[:, None]
        dhtil_c = jnp.where(act, dhtil, 0.0)
    else:
        act, dhtil_c = None, dhtil
    dpre = dhtil_c * (1.0 - htil_t * htil_t)
    ctxv = jnp.einsum("bs,bsh->bh", alpha_t, eo32,
                      preferred_element_type=F32)
    wc = w_comb[...].astype(F32)
    dwc_s[:H] = dwc_s[:H] + jnp.dot(ctxv.T, dpre,
                                    preferred_element_type=F32)
    dwc_s[H:] = dwc_s[H:] + jnp.dot(cur.T, dpre,
                                    preferred_element_type=F32)
    dctx = jnp.dot(dpre, wc[:H].T, preferred_element_type=F32)
    dcur = jnp.dot(dpre, wc[H:].T, preferred_element_type=F32)
    dalpha = jnp.einsum("bh,bsh->bs", dctx, eo32,
                        preferred_element_type=F32)
    deo_s[...] = deo_s[...] + jnp.einsum("bs,bh->bsh", alpha_t, dctx,
                                         preferred_element_type=F32)
    dscores = alpha_t * (dalpha - jnp.sum(alpha_t * dalpha, -1,
                                          keepdims=True))
    dcur = dcur + jnp.einsum("bs,bsh->bh", dscores, ep32,
                             preferred_element_type=F32)
    dep_s[...] = dep_s[...] + jnp.einsum("bs,bh->bsh", dscores, cur,
                                         preferred_element_type=F32)

    dh_cur = [dh_s[l] for l in range(nl)]
    dh_cur[nl - 1] = dh_cur[nl - 1] + dcur
    new_dh, new_dc = [None] * nl, [None] * nl
    dfeed_prev = None
    for l in reversed(range(nl)):
        if ragged:
            dh_cell = jnp.where(act, dh_cur[l], 0.0)
            dc_cell = jnp.where(act, dc_s[l], 0.0)
        else:
            dh_cell, dc_cell = dh_cur[l], dc_s[l]
        dg, dc_prev = _pw_bwd(gates[l][0].astype(F32),
                              cp[l][0].astype(F32), cc[l][0].astype(F32),
                              dh_cell, dc_cell)
        new_dh[l] = _pl_bp(dg, site_w[1 + l], ids_refs[1 + l],
                           m_refs[1 + l], r, descs[1 + l], H)
        _pl_wg(hp[l][0].astype(F32), dg, acc_s[1 + l], ids_refs[1 + l],
               m_refs[1 + l], r, descs[1 + l])
        new_dc[l] = dc_prev
        if ragged:
            new_dh[l] = new_dh[l] + jnp.where(act, 0.0, dh_cur[l])
            new_dc[l] = new_dc[l] + jnp.where(act, 0.0, dc_s[l])
        if l > 0:
            dh_cur[l - 1] = dh_cur[l - 1] + _pl_bp(
                dg, site_w[nl + l], ids_refs[nl + l], m_refs[nl + l], r,
                descs[nl + l], H)
            _pl_wg(hh[l - 1][0].astype(F32), dg, acc_s[nl + l],
                   ids_refs[nl + l], m_refs[nl + l], r, descs[nl + l])
            db_s[l - 1][...] = db_s[l - 1][...] + dg.sum(axis=0)[None]
        else:
            dgx0_r[0] = dg.astype(dgx0_r.dtype)
            dfeed_prev = _pl_bp(dg, site_w[0], ids_refs[0], m_refs[0], r,
                                descs[0], H)
            if ragged:
                dfeed_prev = dfeed_prev + jnp.where(act, 0.0, dhtil)
            _pl_wg(fprev[0].astype(F32), dg, acc_s[0], ids_refs[0],
                   m_refs[0], r, descs[0])
    for l in range(nl):
        dh_s[l] = new_dh[l]
        dc_s[l] = new_dc[l]
    dfeed_s[...] = dfeed_prev

    @pl.when(t == n_steps - 1)
    def _flush():
        dwf_r[...] = acc_s[0][...].astype(dwf_r.dtype)
        for l in range(nl):
            du_rs[l][...] = acc_s[1 + l][...].astype(du_rs[l].dtype)
        for l in range(1, nl):
            dw_rs[l - 1][...] = acc_s[nl + l][...].astype(dw_rs[l - 1].dtype)
            db_rs[l - 1][...] = db_s[l - 1][...].astype(db_rs[l - 1].dtype)
        dwc_r[...] = dwc_s[...].astype(dwc_r.dtype)
        dep_r[...] = dep_s[...].astype(dep_r.dtype)
        deo_r[...] = deo_s[...].astype(deo_r.dtype)
        dh0_r[...] = jnp.stack(new_dh).astype(dh0_r.dtype)
        dc0_r[...] = jnp.stack(new_dc).astype(dc0_r.dtype)
        df0_r[...] = dfeed_prev.astype(df0_r.dtype)


def _pallas_bwd(nl, descs, ops, masks, lengths, res, dout, *, interpret):
    gates_seqs, h_seqs, c_seqs, htil_seq, alpha_seq = res
    d_htil, d_hfin, d_cfin, d_ffin = dout
    T, B, G = ops["gx0"].shape
    H = ops["w_feed"].shape[0]
    S = ops["enc_out"].shape[1]
    ns = 2 * nl
    ragged = lengths is not None
    ids = [masks[i] if d.mode == "structured" else _dummy_ids()
           for i, d in enumerate(descs)]
    lens = lengths.astype(jnp.int32) if ragged else _dummy_lens()
    rev3 = lambda t, *_: (T - 1 - t, 0, 0)
    m_ins, m_specs = [], []
    for i, d in enumerate(descs):
        m_in, m_spec = _m3_inputs(masks[i] if d.mode == "dense" else None,
                                  F32, d.fixed, rev=rev3)
        m_ins.append(m_in)
        m_specs.append(m_spec)

    h_prev_seqs = tuple(
        jnp.concatenate([ops["h0"][l][None].astype(F32), h_seqs[l][:-1]])
        for l in range(nl))
    c_prev_seqs = tuple(
        jnp.concatenate([ops["c0"][l][None].astype(F32), c_seqs[l][:-1]])
        for l in range(nl))
    feed_prev_seq = jnp.concatenate(
        [ops["feed0"][None].astype(F32), htil_seq[:-1]])

    rev = lambda shp: pl.BlockSpec((1, *shp),
                                   lambda t, *_: (T - 1 - t,) + (0,) * len(shp))
    const = lambda shp: pl.BlockSpec(shp, lambda t, *_: (0,) * len(shp))

    kernel = functools.partial(_pl_bwd_kernel, nl=nl, descs=descs, n_steps=T,
                               ragged=ragged)
    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=ns + 1,
            grid=(T,),
            in_specs=[
                rev((B, H)),                                   # dy
                *([rev((B, G))] * nl),                         # gates_l
                *([rev((B, H))] * (4 * nl)),                   # h/h_prev/c/c_prev
                rev((B, H)), rev((B, H)), rev((B, S)),         # htil/fprev/alpha
                *([const((H, G))] * nl),                       # U_l
                *([const((H, G))] * (nl - 1)),                 # W_l
                const((H, G)), const((2 * H, H)),              # w_feed/w_comb
                const((B, S, H)), const((B, S, H)),            # enc mem
                const((nl, B, H)), const((nl, B, H)),          # dhT/dcT
                const((B, H)),                                 # dfT
                *m_specs,
            ],
            out_specs=[
                rev((B, G)),                                   # dgx0
                *([const((H, G))] * nl),                       # dU_l
                *([const((H, G))] * (nl - 1)),                 # dW_l
                *([const((1, G))] * (nl - 1)),                 # db_l
                const((H, G)), const((2 * H, H)),              # dWf/dWcomb
                const((B, S, H)), const((B, S, H)),            # dEp/dEo
                const((nl, B, H)), const((nl, B, H)),          # dh0/dc0
                const((B, H)),                                 # dfeed0
            ],
            scratch_shapes=[pltpu.VMEM((nl, B, H), F32),
                            pltpu.VMEM((nl, B, H), F32),
                            pltpu.VMEM((B, H), F32)]
            + [pltpu.VMEM((H, G), F32)] * ns
            + [pltpu.VMEM((1, G), F32)] * (nl - 1)
            + [pltpu.VMEM((2 * H, H), F32),
               pltpu.VMEM((B, S, H), F32), pltpu.VMEM((B, S, H), F32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((T, B, G), F32),
                   *[jax.ShapeDtypeStruct((H, G), F32)] * (2 * nl - 1),
                   *[jax.ShapeDtypeStruct((1, G), F32)] * (nl - 1),
                   jax.ShapeDtypeStruct((H, G), F32),
                   jax.ShapeDtypeStruct((2 * H, H), F32),
                   jax.ShapeDtypeStruct((B, S, H), F32),
                   jax.ShapeDtypeStruct((B, S, H), F32),
                   jax.ShapeDtypeStruct((nl, B, H), F32),
                   jax.ShapeDtypeStruct((nl, B, H), F32),
                   jax.ShapeDtypeStruct((B, H), F32)],
        interpret=interpret,
    )(*ids, lens, d_htil, *gates_seqs, *h_seqs, *h_prev_seqs, *c_seqs,
      *c_prev_seqs, htil_seq, feed_prev_seq, alpha_seq, *ops["us"],
      *ops["ws"], ops["w_feed"], ops["w_comb"], ops["enc_proj"],
      ops["enc_out"], d_hfin, d_cfin, d_ffin, *m_ins)
    i = 0
    dgx = outs[i]; i += 1                                           # noqa: E702
    dus = list(outs[i:i + nl]); i += nl                             # noqa: E702
    dws = list(outs[i:i + nl - 1]); i += nl - 1                     # noqa: E702
    dbs = [b[0] for b in outs[i:i + nl - 1]]; i += nl - 1           # noqa: E702
    dwf, dwcomb, dep, deo, dh0, dc0, dfeed0 = outs[i:i + 7]
    accs = [dwf] + dus + dws
    return (dgx, accs, dbs, dwcomb, dep, deo, dh0, dc0, dfeed0)


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _decoder_scan(descs, impl, interpret, ops, masks, lengths):
    out, _ = _decoder_scan_fwd(descs, impl, interpret, ops, masks, lengths)
    return out


def _decoder_scan_fwd(descs, impl, interpret, ops, masks, lengths):
    nl = len(ops["us"])
    if impl == "pallas":
        (htil_seq, gates_seqs, h_seqs, c_seqs, alpha_seq,
         finals) = _pallas_fwd(nl, descs, ops, masks, lengths,
                               interpret=interpret)
    else:
        (htil_seq, gates_seqs, h_seqs, c_seqs, alpha_seq,
         finals) = _xla_fwd(nl, descs, ops, masks, lengths)
    h_fin, c_fin, feed_fin = finals
    odt = ops["gx0"].dtype
    out = (htil_seq.astype(odt), h_fin.astype(ops["h0"].dtype),
           c_fin.astype(ops["c0"].dtype), feed_fin.astype(odt))
    return out, (gates_seqs, h_seqs, c_seqs, htil_seq, alpha_seq, ops,
                 masks, lengths)


def _decoder_scan_bwd(descs, impl, interpret, res, dout):
    (gates_seqs, h_seqs, c_seqs, htil_seq, alpha_seq, ops, masks,
     lengths) = res
    nl = len(ops["us"])
    r = (gates_seqs, h_seqs, c_seqs, htil_seq, alpha_seq)
    if impl == "pallas":
        (dgx, accs, dbs, dwcomb, dep, deo, dh0, dc0, dfeed0) = _pallas_bwd(
            nl, descs, ops, masks, lengths, r, dout, interpret=interpret)
    else:
        (dgx, accs, dbs, dwcomb, dep, deo, dh0, dc0, dfeed0) = _xla_bwd(
            nl, descs, ops, masks, lengths, r, dout)
    d_ops = {
        "gx0": dgx.astype(ops["gx0"].dtype),
        "us": tuple(accs[1 + l].astype(ops["us"][l].dtype)
                    for l in range(nl)),
        "ws": tuple(accs[nl + l].astype(ops["ws"][l - 1].dtype)
                    for l in range(1, nl)),
        "bs": tuple(d.astype(b.dtype) for d, b in zip(dbs, ops["bs"])),
        "w_feed": accs[0].astype(ops["w_feed"].dtype),
        "w_comb": dwcomb.astype(ops["w_comb"].dtype),
        "enc_proj": dep.astype(ops["enc_proj"].dtype),
        "enc_out": deo.astype(ops["enc_out"].dtype),
        "score_bias": jnp.zeros_like(ops["score_bias"]),
        "h0": dh0.astype(ops["h0"].dtype),
        "c0": dc0.astype(ops["c0"].dtype),
        "feed0": dfeed0.astype(ops["feed0"].dtype),
    }
    d_masks = tuple(
        None if m is None else
        (_float0_like(m) if d.mode == "structured" else jnp.zeros_like(m))
        for d, m in zip(descs, masks))
    dlens = None if lengths is None else _float0_like(lengths)
    return d_ops, d_masks, dlens


_decoder_scan.defvjp(_decoder_scan_fwd, _decoder_scan_bwd)

_decoder_scan_jit = jax.jit(_decoder_scan, static_argnums=(0, 1, 2))


def decoder_scan(gx0: jax.Array, us: Tuple[jax.Array, ...],
                 ws: Tuple[jax.Array, ...], bs: Tuple[jax.Array, ...],
                 w_feed: jax.Array, w_comb: jax.Array,
                 enc_proj: jax.Array, enc_out: jax.Array,
                 score_bias: jax.Array, h0: jax.Array, c0: jax.Array,
                 feed0: jax.Array, *, sites,
                 impl: str = "xla", interpret: Optional[bool] = None,
                 lengths: Optional[jax.Array] = None):
    """Run the full teacher-forced decoder recurrence in one fused pass.

    gx0: (T, B, 4H) Phase-A gate inputs ``drop(embed_t) @ W_x + b_0``
    (time-batched outside, bias folded in); us: nl recurrent weights
    (H, 4H); ws/bs: the nl-1 upper-layer input weights (H, 4H) / biases
    (4H,); w_feed: (H, 4H) input-feed projection; w_comb: (2H, H);
    enc_proj = enc_out @ w_att and enc_out: (B, S, H) resident encoder
    memory; score_bias: (B, S) additive attention mask (0 kept / -1e30
    padded); h0/c0: (nl, B, H); feed0: (B, H). ``sites`` gives the 2*nl
    in-scan dropout sites in canonical order [feed, rh_0..rh_{nl-1},
    nr_1..nr_{nl-1}], each (keep_blocks|None, dense_mask|None, block_size,
    scale) — see the module docstring. Returns ``(h_tildes (T, B, H),
    (h_fin (nl, B, H), c_fin, feed_fin (B, H)))``, differentiable w.r.t.
    every array input (score_bias gets zero cotangent) through the fused
    hand-derived reverse-time backward.

    ``lengths`` (B,) int32 makes the target batch ragged: row b freezes
    every carry (h_l, c_l, feed) after its ``lengths[b]``-th step, so
    ``h_tildes[t, b]`` repeats the last valid readout for
    ``t >= lengths[b]``, finals are the states at the last real step, and
    frozen steps contribute exactly zero to every weight/attention
    gradient — equivalent to running each row unpacked at its own length.
    """
    nl = len(us)
    if len(sites) != 2 * nl:
        raise ValueError(f"need {2 * nl} site entries, got {len(sites)}")
    pairs = [_mk_site(*s) for s in sites]
    descs = tuple(p[0] for p in pairs)
    site_masks = tuple(p[1] for p in pairs)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    ops = dict(gx0=gx0, us=tuple(us), ws=tuple(ws), bs=tuple(bs),
               w_feed=w_feed, w_comb=w_comb, enc_proj=enc_proj,
               enc_out=enc_out, score_bias=score_bias, h0=h0, c0=c0,
               feed0=feed0)
    htil, h_fin, c_fin, feed_fin = _decoder_scan_jit(
        descs, impl, bool(interpret), ops, site_masks, lengths)
    return htil, (h_fin, c_fin, feed_fin)
