"""Pure-jnp oracles for every Pallas kernel (ground truth for allclose tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _unit_ids(keep_blocks, block_size):
    offs = jnp.arange(block_size, dtype=jnp.int32)
    return (keep_blocks[:, None] * block_size + offs[None, :]).reshape(-1)


def gather_matmul_ref(a, b, keep_blocks, *, block_size, gather, a_is_compact=False,
                      transpose_b=False):
    """Oracle for kernels.gather_matmul (all variants), fp32 accumulation.

    gather="b_rows", not transpose_b:
        y = a_c @ b[kept_rows, :]      (a gathered on cols unless a_is_compact)
    gather="b_rows", transpose_b:
        y = a @ b[kept_rows, :].T      (compact output over kept blocks)
    gather="b_cols":
        y = a @ b[:, kept_cols]        (compact output over kept blocks)
    """
    ids = _unit_ids(keep_blocks, block_size)
    if gather == "b_rows" and not transpose_b:
        a_c = a if a_is_compact else jnp.take(a, ids, axis=1)
        y = jnp.dot(a_c, jnp.take(b, ids, axis=0),
                    preferred_element_type=jnp.float32)
    elif gather == "b_rows" and transpose_b:
        y = jnp.dot(a, jnp.take(b, ids, axis=0).T,
                    preferred_element_type=jnp.float32)
    elif gather == "b_cols":
        y = jnp.dot(a, jnp.take(b, ids, axis=1),
                    preferred_element_type=jnp.float32)
    else:
        raise ValueError(gather)
    return y.astype(a.dtype)


def gather_matmul_stepped_ref(a, b, keep_blocks, *, block_size,
                              a_is_compact=False, transpose_b=False):
    """Oracle for kernels.gather_matmul_stepped: per-step ids table.

    a: (T, M, ·); keep_blocks: (T, nk). Each step t runs the corresponding
    single-mask gather_matmul_ref against its own kept blocks.
    """
    def one(a_t, kb_t):
        return gather_matmul_ref(a_t, b, kb_t, block_size=block_size,
                                 gather="b_rows", a_is_compact=a_is_compact,
                                 transpose_b=transpose_b)
    return jax.vmap(one)(a, keep_blocks)


def lstm_scan_ref(gx, u, h0, c0, *, keep_blocks=None, dense_mask=None,
                  block_size=1, scale=1.0, forget_bias=0.0):
    """Oracle for kernels.lstm_scan: plain per-step jnp recurrence.

    gx: (T, B, 4H) precomputed ``x@W + b``; u: (H, 4H); RH dropout given as
    a (T|1, nk) kept-block ids table or a (T|1, B, H) dense mask (leading 1
    = FIXED: the one mask reused every step). Compact semantics: the
    structured path gathers kept columns of h and rows of u per step, like
    the scheduled engine's in-scan ``sdrop_matmul``. Differentiable via
    plain autodiff-of-scan (the independent ground truth for the fused
    custom_vjp).
    """
    T = gx.shape[0]
    h, c = h0, c0
    hs = []
    for t in range(T):
        if keep_blocks is not None:
            kb_t = keep_blocks[0 if keep_blocks.shape[0] == 1 else t]
            ids = _unit_ids(kb_t, block_size)
            r = jnp.dot(jnp.take(h, ids, axis=-1), jnp.take(u, ids, axis=0),
                        preferred_element_type=jnp.float32) * scale
        elif dense_mask is not None:
            m_t = dense_mask[0 if dense_mask.shape[0] == 1 else t]
            r = jnp.dot(h * m_t * scale, u,
                        preferred_element_type=jnp.float32)
        else:
            r = jnp.dot(h, u, preferred_element_type=jnp.float32)
        gates = gx[t].astype(jnp.float32) + r
        h, c = lstm_pointwise_ref(gates, c, forget_bias=forget_bias)
        hs.append(h)
    return jnp.stack(hs), (h, c)


def slstm_scan_ref(xg, r, h0, c0, n0, m0, *, keep_blocks=None,
                   dense_mask=None, block_size=1, scale=1.0):
    """Oracle for kernels.slstm_scan: plain per-step jnp recurrence.

    xg: (T, B, H, 4dh) precomputed gate inputs in (i, f, z, o)-per-head
    layout; r: (H, dh, 4dh) per-head block-diagonal recurrent weights;
    h0/c0/n0/m0: (B, H, dh). RH dropout over the dh axis, shared across
    heads: a (T|1, nk) kept-block ids table or a (T|1, B, 1|H, dh) dense
    mask (leading 1 = FIXED). The per-step math mirrors
    ``models/xlstm.py slstm_step`` (exponential gating, (n, m)
    normalizer/stabilizer, eps=1e-6 floor). Differentiable via plain
    autodiff-of-loop (the independent ground truth for the fused
    custom_vjp).
    """
    T = xg.shape[0]
    f32 = jnp.float32
    h, c, n, m = (a.astype(f32) for a in (h0, c0, n0, m0))
    hs = []
    for t in range(T):
        if keep_blocks is not None:
            kb_t = keep_blocks[0 if keep_blocks.shape[0] == 1 else t]
            ids = _unit_ids(kb_t, block_size)
            rr = jnp.einsum("bhk,hkg->bhg", jnp.take(h, ids, axis=-1),
                            jnp.take(r, ids, axis=1),
                            preferred_element_type=f32) * scale
        elif dense_mask is not None:
            m_t = dense_mask[0 if dense_mask.shape[0] == 1 else t]
            rr = jnp.einsum("bhd,hdg->bhg", h * m_t.astype(f32) * scale, r,
                            preferred_element_type=f32)
        else:
            rr = jnp.einsum("bhd,hdg->bhg", h, r, preferred_element_type=f32)
        gates = xg[t].astype(f32) + rr
        gi, gf, gz, go = jnp.split(gates, 4, axis=-1)
        lf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(lf + m, gi)
        i = jnp.exp(gi - m_new)
        f = jnp.exp(lf + m - m_new)
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        c = f * c + i * z
        n = f * n + i
        m = m_new
        h = o * (c / jnp.maximum(n, 1e-6))
        hs.append(h)
    return jnp.stack(hs), (h, (c, n, m))


def decoder_scan_ref(gx0, us, ws, bs, w_feed, w_comb, enc_proj, enc_out,
                     score_bias, h0, c0, feed0, *, sites):
    """Oracle for kernels.decoder_scan: plain per-step jnp decoder loop.

    Same signature/site contract as ``decoder_scan`` (canonical order
    [feed, rh_0..rh_{nl-1}, nr_1..nr_{nl-1}], each ``(keep_blocks|None,
    dense_mask|None, block_size, scale)``). Per step: layer-0 gates =
    gx0_t + drop(feed) @ w_feed + drop(h_0) @ u_0; upper layers add their
    own NR/RH sites + bias; then Luong general attention with the additive
    ``score_bias`` and the tanh ``w_comb`` readout carried as next step's
    feed. Differentiable via plain autodiff-of-loop (the independent
    ground truth for the fused custom_vjp).
    """
    nl = len(us)
    f32 = jnp.float32

    def drop_mm(x, w, site, t):
        kb, mask, bsz, scale = site
        if kb is not None:
            kb_t = kb[0 if kb.shape[0] == 1 else t]
            ids = _unit_ids(kb_t, bsz)
            return jnp.dot(jnp.take(x, ids, axis=-1),
                           jnp.take(w, ids, axis=0),
                           preferred_element_type=f32) * scale
        if mask is not None:
            m_t = mask[0 if mask.shape[0] == 1 else t]
            return jnp.dot(x * m_t.astype(f32) * scale, w,
                           preferred_element_type=f32)
        return jnp.dot(x, w, preferred_element_type=f32)

    T = gx0.shape[0]
    hs = [h0[l].astype(f32) for l in range(nl)]
    cs = [c0[l].astype(f32) for l in range(nl)]
    feed = feed0.astype(f32)
    ep = enc_proj.astype(f32)
    eo = enc_out.astype(f32)
    sb = score_bias.astype(f32)
    htils = []
    for t in range(T):
        g = (gx0[t].astype(f32) + drop_mm(feed, w_feed, sites[0], t)
             + drop_mm(hs[0], us[0], sites[1], t))
        hs[0], cs[0] = lstm_pointwise_ref(g, cs[0])
        cur = hs[0]
        for l in range(1, nl):
            g = (drop_mm(cur, ws[l - 1], sites[nl + l], t)
                 + bs[l - 1].astype(f32)
                 + drop_mm(hs[l], us[l], sites[1 + l], t))
            hs[l], cs[l] = lstm_pointwise_ref(g, cs[l])
            cur = hs[l]
        scores = jnp.einsum("bh,bsh->bs", cur, ep,
                            preferred_element_type=f32) + sb
        alpha = jax.nn.softmax(scores, axis=-1)
        ctxv = jnp.einsum("bs,bsh->bh", alpha, eo,
                          preferred_element_type=f32)
        feed = jnp.tanh(jnp.dot(jnp.concatenate([ctxv, cur], -1),
                                w_comb.astype(f32),
                                preferred_element_type=f32))
        htils.append(feed)
    return jnp.stack(htils), (jnp.stack(hs), jnp.stack(cs), feed)


def lstm_pointwise_ref(gates, c_prev, *, forget_bias=0.0):
    """Oracle for kernels.lstm_pointwise. gates: (B, 4H) order (i,f,g,o)."""
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    f32 = jnp.float32
    i, f, g, o, c = (t.astype(f32) for t in (i, f, g, o, c_prev))
    c_new = jax.nn.sigmoid(f + forget_bias) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new.astype(gates.dtype), c_new.astype(gates.dtype)
