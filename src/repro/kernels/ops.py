"""Jit'd public wrappers for the Pallas kernels (dispatch layer).

On TPU the kernels run compiled; elsewhere they run in interpret mode
(auto-detected), which executes the kernel body on CPU for correctness.
``ref.py`` holds the independent pure-jnp oracles used by the tests.
"""
from repro.kernels.cell_scan import cell_scan
from repro.kernels.decoder_scan import decoder_scan
from repro.kernels.gather_matmul import gather_matmul, gather_matmul_stepped
from repro.kernels.lstm_pointwise import lstm_pointwise
from repro.kernels.lstm_scan import lstm_scan
from repro.kernels.slstm_scan import slstm_scan

__all__ = ["cell_scan", "decoder_scan", "gather_matmul",
           "gather_matmul_stepped", "lstm_pointwise", "lstm_scan",
           "slstm_scan"]
