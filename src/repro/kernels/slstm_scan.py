"""Fused persistent-scan sLSTM — the xLSTM-cell instance of cell_scan.

The sLSTM (arXiv:2405.04517) is a scalar-memory cell with a true h->h
recurrence — the paper's RH structured-dropout territory — but its
per-step math differs from the vanilla LSTM in three ways:

  * **exponential gating**: the input gate is ``exp(gi)`` and the forget
    gate ``sigmoid`` applied in log space (``lf = log_sigmoid(gf)``), kept
    finite by a running **stabilizer** ``m_t = max(lf_t + m_{t-1}, gi_t)``
    — gates are rescaled by ``exp(-m_t)`` so nothing overflows;
  * **normalizer state**: alongside the cell state ``c`` it carries
    ``n_t = f n_{t-1} + i`` and outputs ``h = o * c / max(n, 1e-6)`` (the
    true, unstabilized h is invariant to m — c and n carry the same
    ``exp(-m)`` factor);
  * **per-head block-diagonal recurrence**: R is (H, dh, 4dh), one block
    per head, which is exactly cell_scan's head-parametric ``u``.

So the carried state is (h, c, n, m) — ``num_states=3`` — and this module
supplies the cell's pointwise forward plus the hand-derived reverse (the
stabilizer max and the normalizer division both have simple local VJPs,
with the max subgradient routed to the selected branch exactly as
autodiff-of-scan does). Everything else — the persistent-scan pallas
kernels with R resident across steps, the scalar-prefetched (T, nk)
schedule-id gathers, the f32 VMEM dR accumulation, the XLA two-pass scan
impl — is cell_scan's shared machinery, so forward AND backward recurrent
matmuls run at (1-p) FLOPs here too.

Gate layout: ``xg`` rows are (i, f, z, o) per head — the layout
``models/xlstm.py`` produces from ``w_gates`` via
``x_gates.reshape(B, H, 4*dh)``. The RH mask is over the per-head dh axis
and shared across heads (compacted matmul shapes stay static across
heads). Serving handoff: the returned final (h, c, n, m) is exactly the
(s_h, s_c, s_n, s_m) layout of ``xlstm.init_state`` — fused-trained
prefill state feeds ``slstm_step`` decode directly, stabilizer included.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.cell_scan import CellSpec, cell_scan

_EPS = 1e-6      # normalizer floor, matches models/xlstm.py slstm_step


def _pointwise_fwd(gates, states):
    """Exponential-gating sLSTM update. gates order (i, f, z, o) per head."""
    c, n, m = states
    gi, gf, gz, go = jnp.split(gates, 4, axis=-1)
    lf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(lf + m, gi)                  # stabilizer
    i = jnp.exp(gi - m_new)
    f = jnp.exp(lf + m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * (c_new / jnp.maximum(n_new, _EPS))
    return h_new, (c_new, n_new, m_new)


def _pointwise_bwd(gates, states_prev, states_new, dh, dstates):
    """Reverse of _pointwise_fwd from pre-activation gates + state seqs.

    dstates carries (dc, dn, dm) from step t+1; dh is the total dL/dh_t.
    The stabilizer max routes its subgradient to the selected branch (the
    forget path where lf + m_prev >= gi, else the input gate) — ties are
    measure-zero with continuous inputs, matching autodiff of the scan.
    """
    c_prev, n_prev, m_prev = states_prev
    c, n, m = states_new                             # m == m_new
    dc_in, dn_in, dm_in = dstates
    gi, gf, gz, go = jnp.split(gates, 4, axis=-1)
    lf = jax.nn.log_sigmoid(gf)
    i = jnp.exp(gi - m)
    f = jnp.exp(lf + m_prev - m)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    inv = 1.0 / jnp.maximum(n, _EPS)
    do = dh * c * inv
    dc_t = dc_in + dh * o * inv
    # d h / d n flows only where the floor is not active.
    dn_t = dn_in - jnp.where(n > _EPS, dh * o * c * inv * inv, 0.0)
    df = dc_t * c_prev + dn_t * n_prev
    di = dc_t * z + dn_t
    dz = dc_t * i
    # i and f both divide by exp(m_new): total into the stabilizer, then
    # routed through the max to its selected branch.
    dm_t = dm_in - di * i - df * f
    sel = (lf + m_prev) >= gi
    dgi = di * i + jnp.where(sel, 0.0, dm_t)
    dlf = df * f + jnp.where(sel, dm_t, 0.0)
    dm_prev = df * f + jnp.where(sel, dm_t, 0.0)
    dgates = jnp.concatenate([
        dgi,
        dlf * jax.nn.sigmoid(-gf),                   # d log_sigmoid
        dz * (1.0 - z * z),
        do * o * (1.0 - o),
    ], axis=-1)
    return dgates, (dc_t * f, dn_t * f, dm_prev)


SLSTM_CELL = CellSpec(name="slstm", num_states=3,
                      pointwise_fwd=_pointwise_fwd,
                      pointwise_bwd=_pointwise_bwd)


def slstm_scan(xg: jax.Array, r: jax.Array, h0: jax.Array, c0: jax.Array,
               n0: jax.Array, m0: jax.Array, *,
               keep_blocks: Optional[jax.Array] = None,
               dense_mask: Optional[jax.Array] = None,
               block_size: int = 1,
               scale: float = 1.0,
               impl: str = "pallas",
               interpret: Optional[bool] = None,
               lengths: Optional[jax.Array] = None):
    """Run the full sLSTM time recurrence in one fused pass.

    xg: (T, B, H, 4dh) precomputed non-recurrent gate inputs
    ``x_t @ W + b`` in (i, f, z, o)-per-head layout (Phase A, bias folded
    in); r: (H, dh, 4dh) per-head block-diagonal recurrent weights;
    h0/c0/n0/m0: (B, H, dh) initial hidden/cell/normalizer/stabilizer
    (fresh start: zeros, zeros, zeros, -1e30). RH dropout over the dh
    axis, shared across heads: ``keep_blocks`` (T|1, nk) structured ids
    table OR ``dense_mask`` (T|1, B, 1|H, dh), with inverted-dropout
    ``scale``; a leading 1 means FIXED. Returns
    ``(hs (T, B, H, dh), (h_fin, (c_fin, n_fin, m_fin)))``, differentiable
    w.r.t. (xg, r, h0, c0, n0, m0) through the fused reverse-time
    backward. ``lengths`` (B,) int32 makes the batch ragged: row b
    freezes its (h, c, n, m) carry after step ``lengths[b]`` and frozen
    steps contribute zero gradient (``cell_scan.cell_scan`` contract).
    """
    return cell_scan(xg, r, h0, (c0, n0, m0), cell=SLSTM_CELL,
                     keep_blocks=keep_blocks, dense_mask=dense_mask,
                     block_size=block_size, scale=scale, impl=impl,
                     interpret=interpret, lengths=lengths)
