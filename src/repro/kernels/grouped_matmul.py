"""Grouped (per-expert) matmul Pallas kernel — the MoE dispatch fused away.

EXPERIMENTS §Perf (mixtral iteration 3) measured ~30% of the post-local-
routing memory term as pure dispatch movement (gathers/scatters/slices
around the expert matmul). This kernel removes it: after the per-shard
sort, every expert's tokens are CONTIGUOUS rows of the sorted buffer, so
the expert compute is

    y[i] = x_sorted[i] @ w[expert_of_row(i)]

with no (E, C, D) capacity buffer at all. The only metadata is a per-row-
block expert id (row blocks never straddle experts because the host pads
each expert's count to the block size), scalar-prefetched into SMEM and
used by the W BlockSpec index_map — the same zero-cost-gather pattern as
``gather_matmul``.

Grid (T/bm, F/bf, D/bk), K innermost, fp32 VMEM accumulator. Validated in
interpret mode against ``grouped_matmul_ref`` (tests/test_grouped.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(blk_e_ref, x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "bk", "interpret"))
def grouped_matmul(x: jax.Array, w: jax.Array, blk_expert: jax.Array, *,
                   bm: int = 128, bf: Optional[int] = None,
                   bk: Optional[int] = None,
                   interpret: Optional[bool] = None) -> jax.Array:
    """x: (T, D) expert-sorted rows (T % bm == 0, blocks expert-pure);
    w: (E, D, F); blk_expert: (T//bm,) int32 expert id per row block.
    -> y: (T, F)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    T, D = x.shape
    E, _, F = w.shape
    assert T % bm == 0, (T, bm)
    bf = bf or min(128, F)
    bk = bk or min(128, D)
    assert F % bf == 0 and D % bk == 0, (F, bf, D, bk)
    grid = (T // bm, F // bf, D // bk)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, k, be: (i, k))
    w_spec = pl.BlockSpec((1, bk, bf), lambda i, j, k, be: (be[i], k, j))
    o_spec = pl.BlockSpec((bm, bf), lambda i, j, k, be: (i, j))

    def kernel(be_ref, x_ref, w_ref, o_ref, acc_ref):
        _kernel(be_ref, x_ref, w_ref.at[0], o_ref, acc_ref, nk=grid[2])

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[x_spec, w_spec],
            out_specs=o_spec,
            scratch_shapes=[pltpu.VMEM((bm, bf), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((T, F), x.dtype),
        interpret=interpret,
    )(blk_expert, x, w)


def plan_groups(counts: jax.Array, bm: int, capacity_blocks: int
                ) -> Tuple[jax.Array, jax.Array]:
    """Host/trace-side helper: per-expert token counts -> (row offsets into
    the padded sorted buffer, per-row-block expert ids).

    Each expert's region is padded up to a multiple of ``bm`` and capped at
    ``capacity_blocks`` blocks, so row blocks are expert-pure and the total
    padded length is static: T_pad = E * capacity_blocks * bm.
    """
    E = counts.shape[0]
    blocks = jnp.clip((counts + bm - 1) // bm, 0, capacity_blocks)
    # static layout: expert e owns block slots [e*capacity_blocks, ...)
    blk_expert = jnp.repeat(jnp.arange(E, dtype=jnp.int32), capacity_blocks)
    offsets = jnp.arange(E, dtype=jnp.int32) * capacity_blocks * bm
    return offsets, blk_expert
