"""Fused LSTM gate pointwise Pallas kernel.

After the (compacted) gate matmuls produce ``gates = xW + hU + b`` (B, 4H),
the cell update is 8 elementwise HBM round-trips if left to XLA on a memory-
bound part of the step. This kernel keeps one (bm, bh) tile of all four gates
plus c_prev resident in VMEM and emits h', c' in a single pass:

    c' = sigmoid(f + fb) * c + sigmoid(i) * tanh(g)
    h' = sigmoid(o) * tanh(c')

Gate layout matches core.lstm: gates[:, 0:H]=i, [H:2H]=f, [2H:3H]=g, [3H:4H]=o.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(i_ref, f_ref, g_ref, o_ref, c_ref, h_out, c_out, *, forget_bias):
    i = i_ref[...].astype(jnp.float32)
    f = f_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    o = o_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    c_new = jax.nn.sigmoid(f + forget_bias) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    h_out[...] = h_new.astype(h_out.dtype)
    c_out[...] = c_new.astype(c_out.dtype)


@functools.partial(jax.jit, static_argnames=("forget_bias", "bm", "bh", "interpret"))
def lstm_pointwise(gates: jax.Array, c_prev: jax.Array, *,
                   forget_bias: float = 0.0,
                   bm: Optional[int] = None,
                   bh: Optional[int] = None,
                   interpret: Optional[bool] = None):
    """gates: (B, 4H), c_prev: (B, H) -> (h', c') each (B, H)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H4 = gates.shape
    H = H4 // 4
    assert c_prev.shape == (B, H)
    bm = bm or min(128, B)
    bh = bh or min(512, H)
    # Require exact tiling; callers pad (LSTM hidden sizes are config-chosen).
    if B % bm or H % bh:
        pad_b, pad_h = (-B) % bm, (-H) % bh
        gates = jnp.pad(gates.reshape(B, 4, H), ((0, pad_b), (0, 0), (0, pad_h))
                        ).reshape(B + pad_b, 4 * (H + pad_h))
        c_prev = jnp.pad(c_prev, ((0, pad_b), (0, pad_h)))
        h, c = lstm_pointwise(gates, c_prev, forget_bias=forget_bias,
                              bm=bm, bh=bh, interpret=interpret)
        return h[:B, :H], c[:B, :H]

    grid = (B // bm, H // bh)
    Hp = H

    def gate_spec(idx):
        return pl.BlockSpec((bm, bh), lambda i, j: (i, idx * (Hp // bh) + j))

    specs = [gate_spec(0), gate_spec(1), gate_spec(2), gate_spec(3),
             pl.BlockSpec((bm, bh), lambda i, j: (i, j))]
    out_spec = pl.BlockSpec((bm, bh), lambda i, j: (i, j))
    h, c = pl.pallas_call(
        functools.partial(_kernel, forget_bias=forget_bias),
        grid=grid,
        in_specs=specs,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((B, H), gates.dtype),
                   jax.ShapeDtypeStruct((B, H), gates.dtype)],
        interpret=interpret,
    )(gates, gates, gates, gates, c_prev)
    return h, c
