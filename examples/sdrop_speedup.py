"""Measure the paper's FP/BP/WG speedups in isolation (Table-1 style).

For a Zaremba-large-sized gate matmul (B*T x 2H x 4H-ish), times
  dense          : x @ W                      (no dropout)
  NR+Random      : (x * mask) @ W             (baseline: no reclaim)
  NR+ST (paper)  : sdrop_matmul(x, W, keep)   (compacted FP/BP/WG)
at rates {0.5, 0.65} on the CPU backend, reporting per-phase speedup
(FP = fwd, BP+WG = grad), mirroring the paper's Table 1 breakdown.

    PYTHONPATH=src python examples/sdrop_speedup.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import masks, sparse_matmul as sm

B, H, N = 700, 1500, 6000            # Zaremba-large LSTM gate matmul shape


def timeit(f, *args, n=20):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def main():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, H))
    w = jax.random.normal(jax.random.fold_in(key, 1), (H, N)) / H ** 0.5

    dense_f = jax.jit(lambda x, w: x @ w)
    dense_g = jax.jit(jax.grad(lambda x, w: ((x @ w) ** 2).sum(),
                               argnums=(0, 1)))
    t_df = timeit(dense_f, x, w)
    t_dg = timeit(lambda x, w: dense_g(x, w)[0], x, w)
    print(f"dense         : FP {t_df*1e3:7.2f} ms   BP+WG {t_dg*1e3:7.2f} ms")

    for rate in (0.5, 0.65):
        kb = masks.sample_keep_blocks(key, H, rate, 4)
        m = masks.keep_blocks_to_mask(kb, H, 4)

        rand_f = jax.jit(lambda x, w, m: (x * m) @ w)
        rand_g = jax.jit(jax.grad(
            lambda x, w, m: (((x * m) @ w) ** 2).sum(), argnums=(0, 1)))
        t_rf = timeit(rand_f, x, w, m)
        t_rg = timeit(lambda x, w, m: rand_g(x, w, m)[0], x, w, m)

        st_f = jax.jit(lambda x, w, kb: sm.sdrop_matmul(
            x, w, kb, rate=rate, block_size=4))
        st_g = jax.jit(jax.grad(
            lambda x, w, kb: (sm.sdrop_matmul(x, w, kb, rate=rate,
                                              block_size=4) ** 2).sum(),
            argnums=(0, 1)))
        t_sf = timeit(st_f, x, w, kb)
        t_sg = timeit(lambda x, w, kb: st_g(x, w, kb)[0], x, w, kb)

        print(f"rate={rate}:")
        print(f"  NR+Random   : FP {t_rf*1e3:7.2f} ms   BP+WG {t_rg*1e3:7.2f} ms"
              f"   (speedup {t_rf/t_rf:.2f}x / {t_rg/t_rg:.2f}x vs itself)")
        print(f"  NR+ST(paper): FP {t_sf*1e3:7.2f} ms   BP+WG {t_sg*1e3:7.2f} ms"
              f"   speedup vs random: FP {t_rf/t_sf:.2f}x  BP+WG {t_rg/t_sg:.2f}x")


if __name__ == "__main__":
    main()
