"""Measure the paper's speedups: isolated gate matmuls AND the full stack.

Part 1 (Table-1 style, matmul in isolation) — for a Zaremba-large-sized
gate matmul (B x H x 4H-ish), times
  dense          : x @ W                      (no dropout)
  NR+Random      : (x * mask) @ W             (baseline: no reclaim)
  NR+ST (paper)  : sdrop_matmul(x, W, keep)   (compacted FP/BP/WG)
at rates {0.5, 0.65}, reporting per-phase times (FP = fwd, BP+WG = grad)
and the structured-vs-random speedup.

Part 2 (what actually ships) — times the full 2-layer ``lstm_stack``
(fwd + bwd) under dense / case1 / case3 plans on ALL THREE recurrent
engines:
  stepwise  : reference — masks sampled and NR matmuls run inside the scan
  scheduled : two-phase — masks pre-sampled, NR matmuls time-batched
              outside the scan, scan body = RH matmul + pointwise
  fused     : same Phase A; Phase B = one kernels/lstm_scan call per layer
              (persistent U, compact RH gathers, fused pointwise + fused
              reverse-time backward). On CPU this runs the kernel's xla
              impl; the Pallas impl needs a TPU to be fast (interpret mode
              elsewhere is correctness-only).
The scheduled/stepwise and fused/scheduled ratios are the wall-clock value
of the two engine refactors; the case3-vs-case1 ratio is the paper's
structured-sparsity win.

Part 3 (the NMT workload) — times the full seq2seq fwd+bwd on the three
engines. Here the decoder is the interesting part: input feeding chains
every step's gate matmul through the previous step's attention readout,
so stepwise cannot hoist anything. The two-pass fused decoder
(models/seq2seq.py, PR 7) splits the layer-0 fan-in, time-batches the
embedding-side NR matmuls at (1-p) FLOPs in Phase A, and runs the rest of
the recurrence (attention + input feeding included) as one decoder_scan
kernel with a hand-derived backward.

    PYTHONPATH=src python examples/sdrop_speedup.py [--quick]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import lstm as lstm_mod
from repro.core import masks, sparse_matmul as sm
from repro.core.dropout_plan import DropoutPlan
from repro.data import synthetic
from repro.models import seq2seq

B, H, N = 700, 1500, 6000            # Zaremba-large LSTM gate matmul shape


def timeit(f, *args, n=20):
    """Median-free simple timer; exactly one warmup invocation."""
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def matmul_phases(n=20):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, H))
    w = jax.random.normal(jax.random.fold_in(key, 1), (H, N)) / H ** 0.5

    dense_f = jax.jit(lambda x, w: x @ w)
    dense_g = jax.jit(jax.grad(lambda x, w: ((x @ w) ** 2).sum(),
                               argnums=(0, 1)))
    t_df = timeit(dense_f, x, w, n=n)
    t_dg = timeit(lambda x, w: dense_g(x, w)[0], x, w, n=n)
    print(f"dense         : FP {t_df*1e3:7.2f} ms   BP+WG {t_dg*1e3:7.2f} ms")

    for rate in (0.5, 0.65):
        kb = masks.sample_keep_blocks(key, H, rate, 4)
        m = masks.keep_blocks_to_mask(kb, H, 4)

        rand_f = jax.jit(lambda x, w, m: (x * m) @ w)
        rand_g = jax.jit(jax.grad(
            lambda x, w, m: (((x * m) @ w) ** 2).sum(), argnums=(0, 1)))
        t_rf = timeit(rand_f, x, w, m, n=n)
        t_rg = timeit(lambda x, w, m: rand_g(x, w, m)[0], x, w, m, n=n)

        st_f = jax.jit(lambda x, w, kb: sm.sdrop_matmul(
            x, w, kb, rate=rate, block_size=4))
        st_g = jax.jit(jax.grad(
            lambda x, w, kb: (sm.sdrop_matmul(x, w, kb, rate=rate,
                                              block_size=4) ** 2).sum(),
            argnums=(0, 1)))
        t_sf = timeit(st_f, x, w, kb, n=n)
        t_sg = timeit(lambda x, w, kb: st_g(x, w, kb)[0], x, w, kb, n=n)

        print(f"rate={rate}:")
        print(f"  NR+Random   : FP {t_rf*1e3:7.2f} ms   "
              f"BP+WG {t_rg*1e3:7.2f} ms   (dense-FLOP baseline)")
        print(f"  NR+ST(paper): FP {t_sf*1e3:7.2f} ms   "
              f"BP+WG {t_sg*1e3:7.2f} ms   speedup vs random: "
              f"FP {t_rf/t_sf:.2f}x  BP+WG {t_rg/t_sg:.2f}x")


def stack_time(plan: DropoutPlan, engine: str, T, Bs, D, Hs, n=8):
    """Full 2-layer lstm_stack fwd+bwd ms/step under one plan + engine."""
    key = jax.random.PRNGKey(0)
    params = lstm_mod.init_lstm_params(key, D, Hs, 2)
    x = jax.random.normal(jax.random.fold_in(key, 2), (T, Bs, D))
    state = lstm_mod.zero_state(2, Bs, Hs)

    @jax.jit
    def step(params, x, key):
        def loss(p):
            ctx = plan.bind(key, 0)
            ys, _ = lstm_mod.lstm_stack(p, x, state, ctx=ctx, engine=engine)
            return (ys ** 2).sum()
        return jax.grad(loss)(params)

    return timeit(step, params, x, key, n=n) * 1e3


def full_stack(quick=False):
    T, Bs, Hs = (16, 8, 256) if quick else (35, 20, 1024)
    D = Hs
    n = 4 if quick else 8
    plans = {
        "dense": DropoutPlan.off(),
        "case1": DropoutPlan.case("case1", 0.5, sites=("nr", "rh")),
        "case3": DropoutPlan.case("case3", 0.5, block_size=4,
                                  sites=("nr", "rh")),
    }
    print(f"\nfull 2-layer lstm_stack fwd+bwd (T={T}, B={Bs}, H={Hs}):")
    times = {}
    for name, plan in plans.items():
        for engine in ("stepwise", "scheduled", "fused"):
            times[(name, engine)] = stack_time(plan, engine, T, Bs, D, Hs,
                                               n=n)
            print(f"  {name:6s} {engine:9s}: "
                  f"{times[(name, engine)]:8.1f} ms/step")
    for name in plans:
        r = times[(name, "stepwise")] / times[(name, "scheduled")]
        rf = times[(name, "scheduled")] / times[(name, "fused")]
        print(f"  {name:6s} scheduled-engine speedup: {r:.2f}x   "
              f"fused vs scheduled: {rf:.2f}x")
    r13 = times[("case1", "scheduled")] / times[("case3", "scheduled")]
    print(f"  case3 vs case1 (scheduled engine):    {r13:.2f}x "
          f"(structured-sparsity reclaim; needs paper-scale H to pay for "
          f"its gathers — run without --quick)")


def nmt_decoder(quick=False):
    """Full seq2seq fwd+bwd per engine: prices the two-pass fused decoder
    against the in-scan oracle on the input-feeding NMT workload."""
    H = 192 if quick else 512
    S = 16 if quick else 40
    Bn = 8 if quick else 16
    n = 3 if quick else 6
    plan = DropoutPlan.case("case3", 0.3, block_size=8,
                            sites=("nr", "rh", "out"))
    cfg = seq2seq.NMTConfig(src_vocab=1000, tgt_vocab=1000, embed=H,
                            hidden=H, num_layers=2, plan=plan)
    batch = jax.tree.map(jnp.asarray, synthetic.nmt_pairs(
        Bn, 1000, 1000, max_len=S, seed=0))
    params = seq2seq.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(3)
    print(f"\nseq2seq NMT fwd+bwd, case3 rate .3 (2x{H}, B={Bn}, S={S}):")
    times = {}
    for engine in ("stepwise", "scheduled", "fused"):
        c = dataclasses.replace(cfg, engine=engine)
        step = jax.jit(jax.grad(
            lambda p, b, k: seq2seq.loss_fn(p, b, c, drop_key=k)))
        times[engine] = timeit(step, params, batch, key, n=n) * 1e3
        print(f"  {engine:9s}: {times[engine]:8.1f} ms/step")
    print(f"  scheduled-engine speedup: "
          f"{times['stepwise'] / times['scheduled']:.2f}x   "
          f"two-pass fused vs scheduled: "
          f"{times['scheduled'] / times['fused']:.2f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    matmul_phases(n=5 if args.quick else 20)
    full_stack(quick=args.quick)
    nmt_decoder(quick=args.quick)


if __name__ == "__main__":
    main()
