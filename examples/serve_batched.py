"""Serve a small model with batched requests through the DecodeEngine.

Shows both cache kinds: a KV-cache transformer (qwen3 smoke) and a
recurrent-state arch (xlstm smoke — the long_500k serving path), both
prefilled through the SHARED serving/prefill helper and decoded by the
on-device chunked loop; plus the continuous-batching scheduler on a
ragged request trace.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod
from repro.serving import DecodeEngine, Request, prompt_prefill, serve


def _engine(arch: str, batch, max_seq, **kw):
    spec = configs.get_arch(arch)
    cfg = spec.smoke()
    mesh = mesh_mod.make_host_mesh()
    rules = shd.rules_for_mesh(mesh)
    init_fn, _, _, _ = steps_mod.param_setup(spec, cfg, mesh, rules)
    params = init_fn()
    return spec, cfg, params, rules, DecodeEngine(
        spec=spec, cfg=cfg, params=params, max_seq=max_seq, batch=batch,
        rules=rules, mesh=mesh, **kw)


def serve_rect(arch: str, batch=4, prompt_len=12, gen=20):
    """Rectangular: one prompt batch -> one on-device decode dispatch."""
    spec, cfg, params, rules, engine = _engine(arch, batch, prompt_len + gen,
                                               temperature=0.8)
    rng = np.random.default_rng(0)
    vocab = getattr(cfg, "vocab", 128)
    prompt = jnp.asarray(rng.integers(3, vocab, (batch, prompt_len)),
                         jnp.int32)
    t0 = time.time()
    # the shared helper picks native prefill (transformer KV / xlstm) or
    # the masked replay scan (ssm) — no per-arch loop in the entry point
    engine.state, tok0, pos0 = prompt_prefill(spec, cfg, params, prompt,
                                              state=engine.state,
                                              rules=rules)
    out = engine.generate(tok0, gen, start_pos=pos0)
    dt = time.time() - t0
    print(f"{arch:14s} batch={batch} prompt={prompt_len} gen={gen}: "
          f"{dt*1e3:6.0f} ms  sample: {out[0, :10].tolist()}")


def serve_continuous(arch: str, slots=4, n_requests=10):
    """Ragged trace through the continuous-batching scheduler."""
    spec, cfg, params, rules, engine = _engine(arch, slots, 64,
                                               temperature=0.0, chunk=8)
    rng = np.random.default_rng(1)
    vocab = getattr(cfg, "vocab", 128)
    reqs = [Request(rid=i,
                    prompt=rng.integers(3, vocab, int(rng.integers(2, 13))),
                    max_new=int(rng.integers(4, 17)))
            for i in range(n_requests)]
    t0 = time.time()
    outs = serve(engine, reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in outs.values())
    print(f"{arch:14s} continuous: {n_requests} ragged requests over "
          f"{slots} slots -> {total} tok in {dt*1e3:6.0f} ms "
          f"({engine.chunks_run} dispatches)")


if __name__ == "__main__":
    serve_rect("qwen3-8b")      # KV-cache path
    serve_rect("xlstm-1.3b")    # recurrent-state path (long_500k runs here)
    serve_rect("zamba2-1.2b")   # hybrid: SSM state + shared-attention KV
    serve_continuous("xlstm-1.3b")
