"""Serve a small model with batched requests through the DecodeEngine.

Shows both cache kinds: a KV-cache transformer (qwen3 smoke) and a
recurrent-state arch (xlstm smoke — the long_500k serving path).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import adapters
from repro.launch import steps as steps_mod
from repro.launch import mesh as mesh_mod
from repro.distributed import sharding as shd
from repro.serving import DecodeEngine


def serve(arch: str, batch=4, prompt_len=12, gen=20):
    spec = configs.get_arch(arch)
    cfg = spec.smoke()
    mesh = mesh_mod.make_host_mesh()
    rules = shd.rules_for_mesh(mesh)
    init_fn, _, _, _ = steps_mod.param_setup(spec, cfg, mesh, rules)
    params = init_fn()

    engine = DecodeEngine(spec=spec, cfg=cfg, params=params,
                          max_seq=prompt_len + gen, batch=batch, rules=rules,
                          temperature=0.8)
    rng = np.random.default_rng(0)
    vocab = getattr(cfg, "vocab", 128)
    prompt = jnp.asarray(rng.integers(3, vocab, (batch, prompt_len)),
                         jnp.int32)

    t0 = time.time()
    if spec.kind == "transformer":
        engine.prefill({"tokens": prompt})
    else:  # recurrent state: replay prompt through the state
        step = adapters.decode_fn(spec)
        for t in range(prompt_len):
            _, engine.state = step(params, cfg, engine.state,
                                   prompt[:, t:t + 1], t, rules=rules)
    out = engine.generate(prompt[:, -1:], gen, start_pos=prompt_len)
    dt = time.time() - t0
    print(f"{arch:14s} batch={batch} prompt={prompt_len} gen={gen}: "
          f"{dt*1e3:6.0f} ms  sample: {out[0, :10].tolist()}")


if __name__ == "__main__":
    serve("qwen3-8b")      # KV-cache path
    serve("xlstm-1.3b")    # recurrent-state path (what long_500k runs on)
    serve("zamba2-1.2b")   # hybrid: SSM state + shared-attention KV
