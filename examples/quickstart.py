"""Quickstart: the paper's structured dropout as a drop-in replacement.

Trains a small LSTM LM on a synthetic PTB-like stream twice —
  1. Case-I  (random within batch, random in time)  = Zaremba'14 baseline
  2. Case-III (structured in batch, random in time) = the paper (NR+RH+ST)
— and reports both task metric (perplexity) and measured wall-clock per
step. Case-III runs compacted (1-p)-sized matmuls in FP/BP/WG, which is the
whole point of the paper.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core.masks import BatchPattern, TimePattern
from repro.core.sdrop import DropoutSpec
from repro.data import synthetic
from repro.models import lstm_lm
from repro.models.lstm_lm import LMDropouts


RATE = 0.65          # Zaremba-large's rate; bigger rate = bigger reclaim


def make_cfg(case: str):
    if case == "case1":      # random / per-step (no compute reclaim)
        spec = lambda r: DropoutSpec(rate=r, batch_pattern=BatchPattern.RANDOM,
                                     time_pattern=TimePattern.PER_STEP)
    else:                    # case3: structured / per-step (the paper)
        spec = lambda r: DropoutSpec(rate=r,
                                     batch_pattern=BatchPattern.STRUCTURED,
                                     time_pattern=TimePattern.PER_STEP,
                                     block_size=8)
    return lstm_lm.LSTMLMConfig(
        vocab=2000, embed=512, hidden=512, num_layers=2,
        drops=LMDropouts(inp=spec(RATE), nr=spec(RATE), rh=spec(RATE),
                         out=spec(RATE)))


def run(case: str, steps: int = 30, batch: int = 64, seq: int = 32):
    cfg = make_cfg(case)
    key = jax.random.PRNGKey(0)
    params = lstm_lm.init_params(key, cfg)
    stream = synthetic.lm_stream(cfg.vocab, 300_000, seed=1)
    batches = synthetic.token_batches(stream, batch, seq)

    @jax.jit
    def step_fn(params, tokens, labels, key):
        def loss(p):
            return lstm_lm.loss_fn(p, {"tokens": tokens, "labels": labels},
                                   cfg, drop_key=key)
        l, g = jax.value_and_grad(loss)(params)
        params = jax.tree.map(lambda p, g: p - 0.5 * g, params, g)
        return params, l

    t0, n = None, 0
    for i, (tok, lab) in enumerate(batches):
        if i >= steps:
            break
        params, l = step_fn(params, jnp.asarray(tok), jnp.asarray(lab),
                            jax.random.fold_in(key, i))
        if i == 2:           # skip compile
            t0 = time.time()
        if i >= 2:
            n += 1
    dt = (time.time() - t0) / max(n, 1)
    tok, lab = next(synthetic.token_batches(stream[100_000:], batch, seq))
    ppl = lstm_lm.perplexity(params, jnp.asarray(tok), jnp.asarray(lab), cfg)
    return float(l), ppl, dt


if __name__ == "__main__":
    print("training Case-I (random dropout — baseline, no compute reclaim)")
    l1, p1, t1 = run("case1")
    print(f"  final loss {l1:.3f}  val ppl {p1:.1f}  {t1*1e3:.1f} ms/step")
    print("training Case-III (structured dropout — the paper, NR+RH+ST)")
    l3, p3, t3 = run("case3")
    print(f"  final loss {l3:.3f}  val ppl {p3:.1f}  {t3*1e3:.1f} ms/step")
    from repro.core import masks
    kept = masks.kept_units(512, RATE, 8) / 512
    print(f"\nspeedup (wall-clock, CPU backend): {t1/t3:.2f}x at equal "
          f"rate {RATE}; ppl {p1:.1f} -> {p3:.1f}")
    print(f"structural matmul reduction: gate matmuls run at "
          f"{kept:.2f}x their dense FLOPs in FP, BP and WG (exact)")
