"""Quickstart: the paper's structured dropout, driven by one DropoutPlan.

The model never changes — the experiment variable is the ``DropoutPlan``
mapping the LM's named application sites ("embed", "nr", "rh", "out") to a
dropout pattern. One line flips the whole taxonomy:

    DropoutPlan.case("case1", rate)                  # Zaremba'14 baseline
    DropoutPlan.case("case3", rate, block_size=8)    # the paper

Choosing a dropout case (paper Fig. 1):
  * case1 — RANDOM x PER_STEP: per-sample masks, re-sampled each time step.
    Best-known regularization; no compute reclaim.
  * case2 — RANDOM x FIXED: Gal'16 / AWD-LSTM variational dropout — one
    mask per sequence.
  * case3 — STRUCTURED x PER_STEP: the paper. All samples drop the same
    units, re-sampled per step: the gate matmuls run compacted to (1-p) of
    their dense FLOPs in FP, BP and WG, at Case-I-level task metrics.
  * case4 — STRUCTURED x FIXED: most restricted; ablation only.

How the plan is executed — the two-phase recurrent engine
---------------------------------------------------------

Since PR 2 the LSTM stack runs on a *scheduled* engine by default
(``cfg.engine="scheduled"``, ``core/lstm.py``):

  Phase A (pre-scan):  ``ctx.schedule(site, T, ...)`` samples every time
      step's mask in one pass (a ``(T, nk)`` keep-block table for
      structured cases, a ``(T, B, H)`` bitmask for random ones; FIXED
      patterns store one broadcast row), and each layer's non-recurrent
      x@W gate matmul runs time-batched outside the ``lax.scan``.
  Phase B (in-scan):   the scan body is just the recurrent h@U matmul +
      the pointwise cell update; gate slices and mask rows ride in as
      scan xs. No PRNG and no NR matmul inside the recurrence.

Since PR 3 there is also ``engine="fused"``: same Phase A, but Phase B runs
as ONE ``kernels/lstm_scan`` call per layer — the recurrent weight stays
resident across all T steps, each step gathers its kept blocks straight
from the scalar-prefetched schedule ids table, and the pointwise update
plus the reverse-time backward are fused into the same pass. Pick fused
for recurrent-dominated LSTM training (its Pallas kernel is the TPU path;
off-TPU it runs an equivalent xla two-pass form — the Pallas impl in
interpret mode on CPU is correctness-only, not fast). ``engine="stepwise"``
keeps the reference in-scan path; all three compute the same function
(tests/test_engine.py), and every trainer accepts an ``--engine`` override
next to ``--dropout``.

This script trains a small LSTM LM on a synthetic PTB-like stream under
case1 and case3 and reports both the task metric (perplexity) and measured
wall-clock per step — the case3 speedup is the paper's whole point, and
the scheduled engine is what turns it into an end-to-end step-time win.

Ragged traffic (PR 8)
---------------------

Production corpora are not rectangular. Any batch may carry a per-row
``lengths`` column: all three engines freeze each row's carries past its
length (zero gradient from padding), and the losses mask accordingly.
``data/pipeline.py PackedBatcher`` goes further — it packs a skewed-length
corpus into length-bucketed batches at a fixed *token budget*, so short
sequences stop paying max_len padding FLOPs. ``run_ragged`` below trains
the identical masked objective both ways and reports effective (real)
tokens/sec; at lognormal lengths packing lands ~1.8x (docs/benchmarks.md).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dropout_plan import DropoutPlan
from repro.data import synthetic
from repro.data.pipeline import PackedBatcher
from repro.models import lstm_lm


RATE = 0.65          # Zaremba-large's rate; bigger rate = bigger reclaim
SITES = ("embed", "nr", "rh", "out")


def make_cfg(case: str):
    block = 8 if case in ("case3", "case4") else 1
    plan = DropoutPlan.case(case, RATE, block_size=block, sites=SITES)
    return lstm_lm.LSTMLMConfig(
        vocab=2000, embed=512, hidden=512, num_layers=2, plan=plan)


def run(case: str, steps: int = 30, batch: int = 64, seq: int = 32):
    cfg = make_cfg(case)
    key = jax.random.PRNGKey(0)
    params = lstm_lm.init_params(key, cfg)
    stream = synthetic.lm_stream(cfg.vocab, 300_000, seed=1)
    batches = synthetic.token_batches(stream, batch, seq)

    @jax.jit
    def step_fn(params, tokens, labels, key, step):
        def loss(p):
            return lstm_lm.loss_fn(p, {"tokens": tokens, "labels": labels},
                                   cfg, drop_key=key, step=step)
        l, g = jax.value_and_grad(loss)(params)
        params = jax.tree.map(lambda p, g: p - 0.5 * g, params, g)
        return params, l

    t0, n = None, 0
    for i, (tok, lab) in enumerate(batches):
        if i >= steps:
            break
        params, l = step_fn(params, jnp.asarray(tok), jnp.asarray(lab),
                            key, jnp.int32(i))
        if i == 2:           # skip compile
            t0 = time.time()
        if i >= 2:
            n += 1
    dt = (time.time() - t0) / max(n, 1)
    tok, lab = next(synthetic.token_batches(stream[100_000:], batch, seq))
    ppl = lstm_lm.perplexity(params, jnp.asarray(tok), jnp.asarray(lab), cfg)
    return float(l), ppl, dt


def run_ragged(steps: int = 20, max_len: int = 64, budget: int = 1024):
    """Token-packed vs rectangular batching on a skewed-length corpus."""
    cfg = make_cfg("case3")
    key = jax.random.PRNGKey(0)
    params = lstm_lm.init_params(key, cfg)
    docs = synthetic.lm_ragged_docs(256, cfg.vocab, max_len, seed=3)

    @jax.jit
    def step_fn(params, batch, key, step):
        def loss(p):
            return lstm_lm.loss_fn(p, batch, cfg, drop_key=key, step=step)
        l, g = jax.value_and_grad(loss)(params)
        return jax.tree.map(lambda p, g: p - 0.5 * g, params, g), l

    def epoch(params, batches, warm):
        tok, t0 = 0, time.time()
        for i, b in enumerate(batches):
            b = {k: jnp.asarray(v) for k, v in b.items()}
            params, l = step_fn(params, b, key, jnp.int32(i))
            tok += int(b["lengths"].sum())
        jax.block_until_ready(l)
        return params, (0 if warm else tok / (time.time() - t0))

    # rectangular: every row padded to max_len, loss masked by lengths
    rows = budget // max_len
    rect = [{k: v[i:i + rows] for k, v in docs.items()}
            for i in range(0, 256, rows)]
    # packed: length-bucketed rows at the same per-batch token budget
    packer = PackedBatcher(docs, budget, seed=0)
    packed = [packer.batch_fn(s) for s in range(packer.steps_per_epoch)]

    for batches in (rect, packed):           # compile both shapes
        params, _ = epoch(params, batches, warm=True)
    params, rect_tps = epoch(params, rect, warm=False)
    params, packed_tps = epoch(params, packed, warm=False)
    util = float(np.mean([b["lengths"].sum() / b["tokens"].size
                          for b in packed]))
    print(f"  rect   {rect_tps:8.0f} real tok/s  (slot util "
          f"{docs['lengths'].mean() / max_len:.2f})")
    print(f"  packed {packed_tps:8.0f} real tok/s  (slot util {util:.2f})"
          f"  -> {packed_tps / max(rect_tps, 1e-9):.2f}x")


if __name__ == "__main__":
    print("training Case-I (random dropout — baseline, no compute reclaim)")
    l1, p1, t1 = run("case1")
    print(f"  final loss {l1:.3f}  val ppl {p1:.1f}  {t1*1e3:.1f} ms/step")
    print("training Case-III (structured dropout — the paper, NR+RH+ST)")
    l3, p3, t3 = run("case3")
    print(f"  final loss {l3:.3f}  val ppl {p3:.1f}  {t3*1e3:.1f} ms/step")
    from repro.core import masks
    kept = masks.kept_units(512, RATE, 8) / 512
    print(f"\nspeedup (wall-clock, CPU backend): {t1/t3:.2f}x at equal "
          f"rate {RATE}; ppl {p1:.1f} -> {p3:.1f}")
    print(f"structural matmul reduction: gate matmuls run at "
          f"{kept:.2f}x their dense FLOPs in FP, BP and WG (exact)")
    print("\nragged corpus, same objective: token-packed vs rectangular")
    run_ragged()
    print("\nthe same pattern on any arch: python -m repro.launch.train "
          "--arch xlstm-1.3b --smoke --dropout case3:0.65:bs8")
    print("engine A/B on any recurrent arch: add --engine stepwise "
          "(reference), --engine scheduled (two-phase, default) or "
          "--engine fused (one persistent-scan kernel per layer)")
