"""End-to-end driver: train a Zaremba-style LSTM LM (~25M params medium /
~66M large) for a few hundred steps with the paper's NR+RH+ST dropout,
checkpointing and auto-resume included.

    PYTHONPATH=src python examples/train_ptb.py --steps 300
    PYTHONPATH=src python examples/train_ptb.py --large --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro import optim
from repro.core.dropout_plan import DropoutPlan
from repro.data import synthetic
from repro.models import lstm_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=20)     # paper: 20
    ap.add_argument("--seq", type=int, default=35)       # paper: 35 unroll
    ap.add_argument("--ckpt-dir", default="/tmp/ptb_ckpt")
    ap.add_argument("--block-size", type=int, default=2,
                    help="structured-dropout block; must divide hidden "
                         "(650 medium / 1500 large -> 2 works for both)")
    ap.add_argument("--engine", default="scheduled",
                    choices=["scheduled", "stepwise"],
                    help="recurrent engine (scheduled = two-phase default)")
    args = ap.parse_args()

    rate = 0.65 if args.large else 0.5
    mk = lstm_lm.zaremba_large if args.large else lstm_lm.zaremba_medium
    cfg = mk(plan=DropoutPlan.case("case3", rate, block_size=args.block_size,
                                   sites=("embed", "nr", "rh", "out")),
             engine=args.engine)
    print(f"config: {cfg.name}  hidden={cfg.hidden}  vocab={cfg.vocab}  "
          f"NR+RH+ST rate={rate}  engine={cfg.engine}")

    key = jax.random.PRNGKey(0)
    params = lstm_lm.init_params(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    opt = optim.chain(optim.clip_by_global_norm(5.0),
                      optim.sgd(optim.step_decay(1.0, 0.5, every=2000,
                                                 start=4000)))
    opt_state = opt.init(params)
    start = 0
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest is not None:
        (params, opt_state), start = ckpt.restore_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"[resume] from step {start}")

    stream = synthetic.lm_stream(cfg.vocab, 2_000_000, seed=0)
    val_tok, val_lab = next(synthetic.token_batches(
        stream[1_500_000:], args.batch, args.seq))

    @jax.jit
    def step_fn(params, opt_state, tokens, labels, key):
        def loss(p):
            return lstm_lm.loss_fn(p, {"tokens": tokens, "labels": labels},
                                   cfg, drop_key=key)
        l, g = jax.value_and_grad(loss)(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return optim.apply_updates(params, upd), opt_state, l

    batches = list(synthetic.token_batches(stream[:1_500_000], args.batch,
                                           args.seq))
    t0 = time.time()
    for i in range(start, args.steps):
        tok, lab = batches[i % len(batches)]
        params, opt_state, l = step_fn(params, opt_state, jnp.asarray(tok),
                                       jnp.asarray(lab),
                                       jax.random.fold_in(key, i))
        if i % 25 == 0:
            ppl = lstm_lm.perplexity(params, jnp.asarray(val_tok),
                                     jnp.asarray(val_lab), cfg)
            print(f"step {i:4d}  loss {float(l):.3f}  val ppl {ppl:8.1f}  "
                  f"({(time.time()-t0):.0f}s)")
        if (i + 1) % 100 == 0:
            ckpt.save_checkpoint(args.ckpt_dir, i + 1, (params, opt_state))
    ppl = lstm_lm.perplexity(params, jnp.asarray(val_tok),
                             jnp.asarray(val_lab), cfg)
    print(f"final val ppl {ppl:.1f} after {args.steps} steps "
          f"({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
