"""Pytest bootstrap: put src/ on sys.path so ``python -m pytest`` works
without the ``PYTHONPATH=src`` incantation; bound the XLA executable
footprint at module boundaries."""
import os
import sys

import pytest

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


@pytest.fixture(scope="module")
def host_devices():
    """Device count for multi-device (shard_map) tests: SKIPS — never
    errors — when the host has a single device, so a plain 1-device
    ``pytest`` run stays green. CI's distributed job forces 8 CPU devices
    via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    import jax
    n = len(jax.devices())
    if n < 2:
        pytest.skip(
            "multi-device test needs >= 2 host devices; run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return n


@pytest.fixture(scope="module", autouse=True)
def _fresh_jit_cache_per_module():
    """Clear jit caches at every test-module boundary. XLA CPU segfaults
    when a long serial run accumulates a few hundred live executables
    (first hit ~230 tests in, PR 6; reproduced earlier as the suite grew)
    — per-module clearing bounds the footprint for every module instead
    of patching whichever file the crash moved to. Costs only
    recompilation of the handful of graphs shared across modules."""
    import jax
    jax.clear_caches()
