"""Stdlib-only markdown link checker for the docs site.

Checks every ``[text](target)`` in the given markdown files (or the
repo's default doc set) and fails on:

  * relative file targets that do not exist on disk (resolved against the
    containing file's directory);
  * fragment targets (``file.md#section`` or ``#section``) whose heading
    slug is absent from the target file (GitHub-style slugs: lowercase,
    punctuation stripped, spaces -> hyphens);
  * bare intra-repo absolute paths (``/src/...``) — always wrong on
    GitHub, use relative links.

External ``http(s)://`` and ``mailto:`` targets are skipped — CI must not
depend on the network. Inline code spans and fenced code blocks are
stripped before matching so doctest output and shell snippets cannot
produce false links.

Run:  python tools/check_links.py [files...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT = ["README.md", "ROADMAP.md", "PAPER.md", "CHANGES.md",
           *sorted(str(p.relative_to(REPO)) for p in REPO.glob("docs/*.md"))]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^(```|~~~).*?^\1\s*$", re.M | re.S)
CODE_RE = re.compile(r"`[^`]*`")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.M)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for a markdown heading."""
    text = CODE_RE.sub(lambda m: m.group(0)[1:-1], heading)
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.strip().replace(" ", "-")


def anchors(md_path: Path) -> set:
    return {slugify(h) for h in HEADING_RE.findall(
        FENCE_RE.sub("", md_path.read_text(encoding="utf-8")))}


def check_file(md_path: Path) -> list:
    errors = []
    text = FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    text = CODE_RE.sub("", text)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, frag = target.partition("#")
        if path_part.startswith("/"):
            errors.append(f"{md_path}: absolute path link '{target}'")
            continue
        dest = md_path if not path_part else (
            md_path.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{md_path}: broken link '{target}' "
                          f"(no such file: {dest})")
            continue
        if frag and dest.suffix == ".md" and slugify(frag) not in anchors(dest):
            errors.append(f"{md_path}: broken anchor '{target}' "
                          f"(no heading slug '#{slugify(frag)}' in {dest})")
    return errors


def main(argv: list) -> int:
    files = [Path(a) for a in argv] if argv else [REPO / f for f in DEFAULT]
    errors, n_links = [], 0
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file not found")
            continue
        text = CODE_RE.sub("", FENCE_RE.sub(
            "", f.read_text(encoding="utf-8")))
        n_links += len([t for t in LINK_RE.findall(text)
                        if not t.startswith(("http://", "https://"))])
        errors.extend(check_file(f))
    for e in errors:
        print(f"FAIL  {e}")
    print(f"check_links: {len(files)} files, {n_links} local links, "
          f"{len(errors)} errors")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
